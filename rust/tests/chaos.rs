//! Chaos tests: deterministic fault injection ([`brecq::util::faults`])
//! against the store retry layer and the serve daemon's crash isolation.
//!
//! Pinned properties:
//! - an injected transient IO fault at `store.publish` is retried and
//!   the published artifact is bitwise identical to a fault-free run;
//! - a job that panics mid-reconstruction becomes a per-job failure —
//!   the daemon survives and the same spec succeeds on resubmit;
//! - a job past its `deadline_ms` fails with a typed `deadline` error
//!   while its sibling jobs in the batch complete normally;
//! - a daemon killed with SIGKILL mid-batch leaves a journal that a
//!   restarted daemon recovers before binding, after which the batch
//!   replays warm with zero recomputation;
//! - reconstruction interrupted after k of n units resumes from per-unit
//!   checkpoints, recomputing exactly n−k units, bit-identical to an
//!   uninterrupted run at 1/2/8 threads;
//! - a corrupt checkpoint is discarded and costs exactly one recomputed
//!   unit;
//! - a deadline-expired batch leaves its finished units checkpointed and
//!   a resubmit finishes from them.
//!
//! The fault plan is process-global, so every test here serializes on
//! one mutex and clears the plan before releasing it. (The faults
//! module's own unit tests drive `PlanState` directly and never arm the
//! global plan.)

use std::path::PathBuf;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use brecq::coordinator::Env;
use brecq::pipeline::{ArtifactCache, ArtifactStore, EvalScore, JobSpec,
                      Session};
use brecq::util::faults::{self, FaultPlan};
use brecq::util::pool;

/// One lock for every test in this binary: the fault plan (and the
/// daemon sockets under the shared tmp naming) are process-global.
static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn lock_chaos() -> std::sync::MutexGuard<'static, ()> {
    CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Clears the global fault plan when dropped, so a failing assertion
/// cannot leak an armed plan into the next test.
struct DisarmOnDrop;

impl Drop for DisarmOnDrop {
    fn drop(&mut self) {
        faults::set_plan(None);
    }
}

fn env() -> Env {
    Env::bootstrap_synthetic().expect("synthetic environment")
}

fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("brecq_chaos_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn store_cache(dir: &PathBuf) -> ArtifactCache {
    ArtifactCache::with_store(Arc::new(ArtifactStore::open(dir).unwrap()))
}

fn brecq_spec(iters: usize) -> JobSpec {
    JobSpec {
        model: "resnet_s".into(),
        wbits: 4,
        abits: Some(8),
        iters,
        calib_n: 32,
        seed: 0,
        ..JobSpec::default()
    }
}

fn store_session(dir: &PathBuf) -> Session {
    Session::with_store(
        env(),
        Arc::new(ArtifactStore::open(dir).unwrap()),
    )
}

/// Committed checkpoint entries (index files) in a store's pinned
/// `ckpt/` namespace.
fn ckpt_jsons(store_dir: &PathBuf) -> usize {
    std::fs::read_dir(store_dir.join("ckpt"))
        .map(|rd| {
            rd.flatten()
                .filter(|e| {
                    e.path().extension().map_or(false, |x| x == "json")
                })
                .count()
        })
        .unwrap_or(0)
}

// ---------------------------------------------------------------------
// Store retry under injected IO faults
// ---------------------------------------------------------------------

#[test]
fn injected_publish_io_fault_retries_to_a_bitwise_identical_artifact() {
    let _g = lock_chaos();
    let _disarm = DisarmOnDrop;
    // a value whose bit pattern a lossy round-trip would betray
    let val = f64::from_bits(0x3fd5_5555_5555_5555);

    // fault-free reference
    let ref_cache = store_cache(&tmp("retry_ref"));
    let v_ref = ref_cache
        .get_or_build("chaos/retry", || Ok(EvalScore(val)))
        .unwrap();

    // the first publish call fails with a transient IO error
    faults::set_plan(Some(
        FaultPlan::parse("store.publish:io@1", 0).unwrap(),
    ));
    let dir = tmp("retry_faulted");
    let c = store_cache(&dir);
    let v = c
        .get_or_build("chaos/retry", || Ok(EvalScore(val)))
        .unwrap();
    let (calls, fired) = faults::site_counters("store.publish");
    faults::set_plan(None);

    assert_eq!(fired, 1, "the injected fault must actually fire");
    assert!(calls >= 2, "the publish must have been retried");
    assert!(
        c.store().unwrap().stats().retried >= 1,
        "the store must count the retry"
    );
    assert_eq!(
        v.0.to_bits(),
        v_ref.0.to_bits(),
        "value served through the retry must match the reference"
    );

    // the retried publish left a clean entry: a fresh session loads it
    // without computing, and the bits survive the disk round trip
    let c2 = store_cache(&dir);
    let v2: Arc<EvalScore> = c2
        .get_or_build("chaos/retry", || {
            panic!("a published entry must not recompute")
        })
        .unwrap();
    assert_eq!(v2.0.to_bits(), v_ref.0.to_bits());
    assert_eq!(c2.computes(), 0);
    assert_eq!(c2.store().unwrap().stats().corrupt, 0);
}

// ---------------------------------------------------------------------
// Per-unit checkpoint resume
// ---------------------------------------------------------------------

#[test]
fn interrupted_recon_resumes_bitwise_at_each_thread_count() {
    let _g = lock_chaos();
    let _disarm = DisarmOnDrop;
    let spec = brecq_spec(6);

    // fault-free, store-free reference fingerprint
    let ref_fp = {
        let s = Session::new(env());
        format!("{:016x}", s.run(&spec).unwrap().fingerprint())
    };

    let before = pool::threads();
    for &t in &[1usize, 2, 8] {
        pool::set_threads(t);
        let dir = tmp(&format!("resume_t{t}"));

        // interrupt after two committed units (the job.recon site is
        // probed once per non-restored unit; the 3rd probe fails)
        faults::set_plan(Some(
            FaultPlan::parse("job.recon:io@3", 0).unwrap(),
        ));
        let s1 = store_session(&dir);
        let err = s1
            .run(&spec)
            .expect_err("the injected fault must fail the job");
        faults::set_plan(None);
        assert!(
            err.to_string().contains("job.recon"),
            "expected the injected recon fault, got: {err}"
        );
        assert_eq!(
            ckpt_jsons(&dir),
            2,
            "both finished units must be checkpointed (threads={t})"
        );
        assert_eq!(s1.cache().ckpt_written(), 2);

        // a fresh session over the same store resumes: the two
        // checkpointed units replay, the rest recompute
        let s2 = store_session(&dir);
        let out = s2.run(&spec).unwrap();
        assert_eq!(
            format!("{:016x}", out.fingerprint()),
            ref_fp,
            "resumed run must be bit-identical to an uninterrupted \
             one (threads={t})"
        );
        assert_eq!(s2.cache().units_resumed(), 2);
        assert_eq!(
            s2.cache().ckpt_written(),
            out.reports().len() - 2,
            "exactly the non-resumed units recompute"
        );
        assert_eq!(s2.cache().ckpt_corrupt(), 0);
        assert_eq!(
            ckpt_jsons(&dir),
            0,
            "checkpoints must be removed once the final recon \
             artifact publishes"
        );
    }
    pool::set_threads(before);
}

#[test]
fn corrupt_checkpoint_recomputes_exactly_that_unit() {
    let _g = lock_chaos();
    let _disarm = DisarmOnDrop;
    let spec = brecq_spec(6);

    let ref_fp = {
        let s = Session::new(env());
        format!("{:016x}", s.run(&spec).unwrap().fingerprint())
    };

    // interrupt after three committed units
    let dir = tmp("resume_corrupt");
    faults::set_plan(Some(
        FaultPlan::parse("job.recon:io@4", 0).unwrap(),
    ));
    store_session(&dir)
        .run(&spec)
        .expect_err("the injected fault must fail the job");
    faults::set_plan(None);
    assert_eq!(ckpt_jsons(&dir), 3);

    // flip one payload byte of unit 1's checkpoint (the index json
    // carries the full key, which is how we find the right entry)
    let mut target = None;
    for e in std::fs::read_dir(dir.join("ckpt")).unwrap().flatten() {
        let p = e.path();
        if p.extension().map_or(false, |x| x == "json")
            && std::fs::read_to_string(&p).unwrap().contains("/ckpt/1")
        {
            target = Some(p.with_extension("bin"));
        }
    }
    let bin = target.expect("unit 1's checkpoint must be on disk");
    let mut bytes = std::fs::read(&bin).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x40;
    std::fs::write(&bin, bytes).unwrap();

    // resume: units 0 and 2 replay, unit 1 is detected corrupt and
    // recomputed (along with the never-checkpointed tail)
    let s2 = store_session(&dir);
    let out = s2.run(&spec).unwrap();
    assert_eq!(
        format!("{:016x}", out.fingerprint()),
        ref_fp,
        "a corrupt checkpoint must not poison the result"
    );
    assert_eq!(
        s2.cache().ckpt_corrupt(),
        1,
        "the flipped checkpoint must be detected exactly once"
    );
    assert_eq!(
        s2.cache().units_resumed(),
        2,
        "only the two intact checkpoints resume"
    );
    assert_eq!(
        s2.cache().ckpt_written(),
        out.reports().len() - 2,
        "the corrupt unit and the tail recompute"
    );
    assert_eq!(ckpt_jsons(&dir), 0);
}

// ---------------------------------------------------------------------
// Daemon fault isolation (panic, deadline, kill -9)
// ---------------------------------------------------------------------

#[cfg(unix)]
mod serve {
    use super::*;
    use brecq::pipeline::serve::{control, spawn, submit, SubmitSummary};
    use brecq::util::json::Json;
    use std::sync::atomic::{AtomicBool, Ordering};

    fn wait_for_socket(sock: &PathBuf) {
        for _ in 0..600 {
            if sock.exists() {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("daemon socket {sock:?} never appeared");
    }

    fn omse_spec() -> JobSpec {
        JobSpec {
            model: "resnet_s".into(),
            method: brecq::pipeline::Method::Omse,
            wbits: 4,
            calib_n: 32,
            seed: 0,
            ..JobSpec::default()
        }
    }

    fn result_fingerprints(s: &SubmitSummary) -> Vec<String> {
        s.results
            .iter()
            .map(|r| {
                r.as_ref()
                    .expect("job failed")
                    .get("fingerprint")
                    .and_then(Json::as_str)
                    .expect("result carries a fingerprint")
                    .to_string()
            })
            .collect()
    }

    fn done_field(s: &SubmitSummary, field: &str) -> usize {
        s.done
            .get(field)
            .and_then(Json::as_usize)
            .unwrap_or_else(|| panic!("done event carries {field}"))
    }

    #[test]
    fn worker_panic_is_isolated_and_the_daemon_keeps_serving() {
        let _g = lock_chaos();
        let _disarm = DisarmOnDrop;
        let spec = brecq_spec(6);

        // fault-free reference fingerprint (computed while unarmed)
        let ref_fp = {
            let s = Session::new(env());
            format!("{:016x}", s.run(&spec).unwrap().fingerprint())
        };

        let dir = tmp("panic_isolated");
        let sock = dir.join("d.sock");
        let daemon = spawn(Session::new(env()), sock.clone(), 1);
        wait_for_socket(&sock);

        // the first reconstruction unit panics
        faults::set_plan(Some(
            FaultPlan::parse("job.recon:panic@1", 0).unwrap(),
        ));
        let s1 = submit(&sock, &[spec.clone()], 0, None, |_| {})
            .expect("the daemon must survive a panicking job");
        faults::set_plan(None);
        let err = s1.results[0]
            .as_ref()
            .expect_err("the panicked job must fail")
            .clone();
        assert!(
            err.contains("panic") && err.contains("job.recon"),
            "panic must surface as a typed per-job error, got: {err}"
        );
        assert_eq!(done_field(&s1, "failed"), 1);

        // same daemon, same spec, no faults: serves normally
        let s2 = submit(&sock, &[spec], 0, None, |_| {}).unwrap();
        assert_eq!(
            result_fingerprints(&s2),
            vec![ref_fp],
            "post-panic resubmit must be bit-identical to fault-free"
        );
        assert_eq!(done_field(&s2, "failed"), 0);

        control(&sock, "shutdown").unwrap();
        daemon.join().unwrap().unwrap();
    }

    #[test]
    fn deadline_expired_job_fails_typed_while_its_sibling_completes() {
        let _g = lock_chaos();
        let dir = tmp("deadline");
        let sock = dir.join("d.sock");
        let daemon = spawn(Session::new(env()), sock.clone(), 2);
        wait_for_socket(&sock);

        // job 0 cannot finish 400 iterations inside 10ms; job 1 has no
        // deadline and must be untouched by its sibling's cancellation
        let doomed = JobSpec {
            deadline_ms: Some(10),
            ..brecq_spec(400)
        };
        let s = submit(
            &sock,
            &[doomed, omse_spec()],
            0,
            Some(Duration::from_secs(300)),
            |_| {},
        )
        .unwrap();
        let err = s.results[0]
            .as_ref()
            .expect_err("the deadline job must fail")
            .clone();
        assert!(
            err.contains("cancelled") && err.contains("deadline"),
            "expected a typed deadline error, got: {err}"
        );
        assert!(
            s.results[1].is_ok(),
            "sibling job must complete: {:?}",
            s.results[1]
        );
        assert_eq!(done_field(&s, "ok"), 1);
        assert_eq!(done_field(&s, "failed"), 1);

        control(&sock, "shutdown").unwrap();
        daemon.join().unwrap().unwrap();
    }

    /// Child half of the kill -9 test: a daemon over the parent's store
    /// directory. Only runs when the parent set the env var; a plain
    /// `cargo test` run no-ops it. The parent SIGKILLs this process.
    #[test]
    fn chaos_daemon_child_helper() {
        let Some(dir) = std::env::var_os("BRECQ_CHAOS_SERVE_DIR") else {
            return;
        };
        let dir = PathBuf::from(dir);
        let store =
            Arc::new(ArtifactStore::open(dir.join("store")).unwrap());
        let d = spawn(
            Session::with_store(env(), store),
            dir.join("d.sock"),
            2,
        );
        d.join().unwrap().unwrap();
    }

    /// SIGKILLs the child on drop so a failing assertion can't leak a
    /// daemon process.
    struct KillOnDrop(std::process::Child);

    impl Drop for KillOnDrop {
        fn drop(&mut self) {
            let _ = self.0.kill();
            let _ = self.0.wait();
        }
    }

    #[test]
    fn killed_daemon_journal_recovers_and_warm_restart_computes_nothing()
    {
        let _g = lock_chaos();
        let dir = tmp("kill9");
        let sock = dir.join("d.sock");
        let store_dir = dir.join("store");
        let specs = vec![brecq_spec(60), omse_spec()];

        // ground truth from a fresh in-process session, no store
        let refs: Vec<String> = {
            let s = Session::new(env());
            specs
                .iter()
                .map(|sp| {
                    format!("{:016x}", s.run(sp).unwrap().fingerprint())
                })
                .collect()
        };

        let exe = std::env::current_exe().unwrap();
        let mut child = KillOnDrop(
            std::process::Command::new(&exe)
                .args([
                    "chaos_daemon_child_helper",
                    "--exact",
                    "--nocapture",
                ])
                .env("BRECQ_CHAOS_SERVE_DIR", &dir)
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::null())
                .spawn()
                .unwrap(),
        );
        wait_for_socket(&sock);

        // submit, then SIGKILL the daemon once the batch is running
        let saw_stage = AtomicBool::new(false);
        let r = std::thread::scope(|s| {
            let h = s.spawn(|| {
                submit(&sock, &specs, 0, None, |ev| {
                    if ev.get("event").and_then(Json::as_str)
                        == Some("stage")
                    {
                        saw_stage.store(true, Ordering::SeqCst);
                    }
                })
            });
            while !saw_stage.load(Ordering::SeqCst) && !h.is_finished()
            {
                std::thread::sleep(Duration::from_millis(2));
            }
            child.0.kill().unwrap();
            let _ = child.0.wait();
            h.join().unwrap()
        });
        let err = r.expect_err("a killed daemon must not return Ok");
        assert!(
            err.to_string().contains("EOF"),
            "daemon death must be reported as EOF, got: {err}"
        );

        // the interrupted batch left its write-ahead journal behind
        let journal_dir = store_dir.join("journal");
        let journals = |dir: &PathBuf| -> usize {
            std::fs::read_dir(dir)
                .map(|rd| {
                    rd.flatten()
                        .filter(|e| {
                            e.path()
                                .extension()
                                .map_or(false, |x| x == "json")
                        })
                        .count()
                })
                .unwrap_or(0)
        };
        assert!(
            journals(&journal_dir) >= 1,
            "killed daemon must leave an in-flight journal"
        );

        // restart over the same store: recovery runs before the socket
        // binds, so once it appears the journal is consumed
        let daemon = spawn(
            Session::with_store(
                env(),
                Arc::new(ArtifactStore::open(&store_dir).unwrap()),
            ),
            sock.clone(),
            2,
        );
        wait_for_socket(&sock);
        assert_eq!(
            journals(&journal_dir),
            0,
            "recovery must consume the dead daemon's journal"
        );
        let stats = control(&sock, "stats").unwrap();
        assert!(
            stats
                .get("journal_recovered")
                .and_then(Json::as_usize)
                .unwrap_or(0)
                >= 1,
            "stats must report journal recovery: {}",
            stats.to_string()
        );

        // recovery already finished the work: the resubmit is free and
        // bit-identical to the in-process reference
        let warm = submit(
            &sock,
            &specs,
            0,
            Some(Duration::from_secs(300)),
            |_| {},
        )
        .unwrap();
        assert_eq!(result_fingerprints(&warm), refs);
        assert_eq!(
            done_field(&warm, "computes"),
            0,
            "warm resubmit after recovery must compute nothing"
        );

        control(&sock, "shutdown").unwrap();
        daemon.join().unwrap().unwrap();
    }

    #[test]
    fn deadline_expired_batch_resumes_from_checkpoints_on_resubmit() {
        let _g = lock_chaos();
        let _disarm = DisarmOnDrop;
        let spec = brecq_spec(80);

        let ref_fp = {
            let s = Session::new(env());
            format!("{:016x}", s.run(&spec).unwrap().fingerprint())
        };

        let dir = tmp("deadline_resume");
        let sock = dir.join("d.sock");
        let store_dir = dir.join("store");
        let daemon = spawn(
            Session::with_store(
                env(),
                Arc::new(ArtifactStore::open(&store_dir).unwrap()),
            ),
            sock.clone(),
            1,
        );
        wait_for_socket(&sock);

        // Keep resubmitting with a growing deadline until the job fits.
        // Whatever units a failed attempt finished stay checkpointed,
        // and the next attempt's `done` must report exactly that many
        // units resumed — the checkpoint count is read off disk before
        // each attempt, so the equality is exact however the timing
        // falls.
        let mut summary = None;
        for attempt in 0..12u32 {
            let k_before = ckpt_jsons(&store_dir);
            let doomed = JobSpec {
                deadline_ms: Some(100u64 << attempt),
                ..spec.clone()
            };
            let s = submit(
                &sock,
                &[doomed],
                0,
                Some(Duration::from_secs(300)),
                |_| {},
            )
            .unwrap();
            match &s.results[0] {
                Ok(_) => {
                    assert_eq!(
                        done_field(&s, "units_resumed"),
                        k_before,
                        "the finishing attempt must resume every \
                         checkpointed unit"
                    );
                    summary = Some(s);
                    break;
                }
                Err(e) => assert!(
                    e.contains("deadline"),
                    "expected a typed deadline error, got: {e}"
                ),
            }
        }
        let s = summary.expect("some deadline must be long enough");
        assert_eq!(result_fingerprints(&s), vec![ref_fp]);
        assert_eq!(
            ckpt_jsons(&store_dir),
            0,
            "checkpoints must be cleared once the job completes"
        );

        control(&sock, "shutdown").unwrap();
        daemon.join().unwrap().unwrap();
    }

    #[test]
    fn killed_daemon_recovery_resumes_from_unit_checkpoints() {
        let _g = lock_chaos();
        let dir = tmp("kill9_resume");
        let sock = dir.join("d.sock");
        let store_dir = dir.join("store");
        let spec = brecq_spec(60);

        let ref_fp = {
            let s = Session::new(env());
            format!("{:016x}", s.run(&spec).unwrap().fingerprint())
        };

        let exe = std::env::current_exe().unwrap();
        let mut child = KillOnDrop(
            std::process::Command::new(&exe)
                .args([
                    "chaos_daemon_child_helper",
                    "--exact",
                    "--nocapture",
                ])
                .env("BRECQ_CHAOS_SERVE_DIR", &dir)
                .stdout(std::process::Stdio::null())
                .stderr(std::process::Stdio::null())
                .spawn()
                .unwrap(),
        );
        wait_for_socket(&sock);

        // submit, then SIGKILL the daemon as soon as the first unit
        // checkpoint commits — mid-reconstruction by construction
        let r = std::thread::scope(|s| {
            let h = s.spawn(|| {
                submit(&sock, &[spec.clone()], 0, None, |_| {})
            });
            while ckpt_jsons(&store_dir) == 0 && !h.is_finished() {
                std::thread::sleep(Duration::from_millis(2));
            }
            child.0.kill().unwrap();
            let _ = child.0.wait();
            h.join().unwrap()
        });
        r.expect_err("a killed daemon must not return Ok");
        // index files commit by atomic rename, so each one on disk is a
        // complete, loadable checkpoint — this count must resume
        let k = ckpt_jsons(&store_dir);
        assert!(k >= 1, "the kill landed after a checkpoint committed");

        // restart over the same store: journal recovery re-runs the job
        // before binding, replaying exactly the k checkpointed units
        let daemon = spawn(
            Session::with_store(
                env(),
                Arc::new(ArtifactStore::open(&store_dir).unwrap()),
            ),
            sock.clone(),
            2,
        );
        wait_for_socket(&sock);
        let stats = control(&sock, "stats").unwrap();
        let stat = |f: &str| {
            stats
                .get(f)
                .and_then(Json::as_usize)
                .unwrap_or_else(|| panic!("stats carries {f}"))
        };
        assert!(stat("journal_recovered") >= 1);
        assert_eq!(
            stat("units_resumed"),
            k,
            "recovery must resume exactly the units the dead daemon \
             checkpointed: {}",
            stats.to_string()
        );
        assert_eq!(stat("ckpt_corrupt"), 0);
        assert_eq!(
            ckpt_jsons(&store_dir),
            0,
            "recovery finished the job, so its checkpoints are gone"
        );

        // the recovered artifact serves warm and bit-identical
        let warm = submit(
            &sock,
            &[spec],
            0,
            Some(Duration::from_secs(300)),
            |_| {},
        )
        .unwrap();
        assert_eq!(result_fingerprints(&warm), vec![ref_fp]);
        assert_eq!(done_field(&warm, "computes"), 0);
        assert_eq!(done_field(&warm, "units_resumed"), 0);

        control(&sock, "shutdown").unwrap();
        daemon.join().unwrap().unwrap();
    }
}
