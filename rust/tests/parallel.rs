//! Parallel-execution parity properties: the pool-backed kernels and the
//! calibration pipeline must be **bit-identical** to scalar references at
//! 1/2/8 threads — the determinism contract documented in `util::pool`
//! and the README threading section.
//!
//! `pool::set_threads` is process-global, so these tests can interleave
//! with the rest of the suite; that is exactly the property under test —
//! results must not depend on the pool size in effect at any moment.

use std::sync::Mutex;

use brecq::coordinator::Env;
use brecq::eval::{accuracy, EvalParams};
use brecq::recon::{BitConfig, Calibrator, ReconConfig};
use brecq::runtime::native::{conv2d, conv2d_bwd, fc_bwd, fc_fwd};
use brecq::tensor::Tensor;
use brecq::util::pool;
use brecq::util::rng::Rng;

/// `pool::set_threads` is process-global and libtest runs tests
/// concurrently: serialize every test in this binary so the "run at N
/// threads" phases really execute at N threads (otherwise a sibling test
/// could flip the pool size mid-run and the invariance assertions would
/// compare two same-thread-count runs).
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock_pool() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn randn(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
}

/// Overwrite a deterministic sprinkling of elements with the IEEE edge
/// values the GEMM paths must fold bit-exactly: ±0.0 and ±denormals.
fn inject_specials(t: &mut Tensor) {
    for (i, v) in t.data.iter_mut().enumerate() {
        match i % 13 {
            2 => *v = 0.0,
            5 => *v = -0.0,
            7 => *v = 1e-42,   // subnormal
            11 => *v = -1e-42, // negative subnormal
            _ => {}
        }
    }
}

fn bits_of(t: &Tensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

/// TF/XLA 'SAME' padding (mirrors the private helper in runtime::native).
fn same_pads(h: usize, k: usize, s: usize) -> (usize, i64) {
    let out = (h + s - 1) / s;
    let total = ((out - 1) * s + k).saturating_sub(h);
    (out, (total / 2) as i64)
}

/// Scalar reference convolution: the fused single-threaded loop the
/// parallel kernel must reproduce bit-for-bit.
fn conv2d_ref(x: &Tensor, w: &Tensor, stride: usize, groups: usize)
    -> Tensor {
    let (b, cin, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cout, cpg_in, k) = (w.shape[0], w.shape[1], w.shape[2]);
    let cpg_out = cout / groups;
    let (ho, pad_h) = same_pads(h, k, stride);
    let (wo, pad_w) = same_pads(wd, k, stride);
    let mut out = vec![0f32; b * cout * ho * wo];
    for bi in 0..b {
        for oc in 0..cout {
            let gi = oc / cpg_out;
            let wbase = oc * cpg_in * k * k;
            for oh in 0..ho {
                let ih0 = (oh * stride) as i64 - pad_h;
                for ow in 0..wo {
                    let iw0 = (ow * stride) as i64 - pad_w;
                    let mut acc = 0f32;
                    for ic in 0..cpg_in {
                        let ci = gi * cpg_in + ic;
                        let xb = (bi * cin + ci) * h;
                        let wb = wbase + ic * k * k;
                        for kh in 0..k {
                            let ih = ih0 + kh as i64;
                            if ih < 0 || ih >= h as i64 {
                                continue;
                            }
                            let xrow = (xb + ih as usize) * wd;
                            let wrow = wb + kh * k;
                            for kw in 0..k {
                                let iw = iw0 + kw as i64;
                                if iw < 0 || iw >= wd as i64 {
                                    continue;
                                }
                                acc += x.data[xrow + iw as usize]
                                    * w.data[wrow + kw];
                            }
                        }
                    }
                    out[((bi * cout + oc) * ho + oh) * wo + ow] = acc;
                }
            }
        }
    }
    Tensor::new(vec![b, cout, ho, wo], out)
}

/// Scalar reference backward: the fused loop updating both grads in one
/// traversal (the pre-pool implementation).
fn conv2d_bwd_ref(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    groups: usize,
    gout: &Tensor,
) -> (Tensor, Tensor) {
    let (b, cin, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cout, cpg_in, k) = (w.shape[0], w.shape[1], w.shape[2]);
    let cpg_out = cout / groups;
    let (ho, pad_h) = same_pads(h, k, stride);
    let (wo, pad_w) = same_pads(wd, k, stride);
    let mut gx = vec![0f32; x.data.len()];
    let mut gw = vec![0f32; w.data.len()];
    for bi in 0..b {
        for oc in 0..cout {
            let gi = oc / cpg_out;
            let wbase = oc * cpg_in * k * k;
            for oh in 0..ho {
                let ih0 = (oh * stride) as i64 - pad_h;
                for ow in 0..wo {
                    let iw0 = (ow * stride) as i64 - pad_w;
                    let g = gout.data[((bi * cout + oc) * ho + oh) * wo + ow];
                    if g == 0.0 {
                        continue;
                    }
                    for ic in 0..cpg_in {
                        let ci = gi * cpg_in + ic;
                        let xb = (bi * cin + ci) * h;
                        let wb = wbase + ic * k * k;
                        for kh in 0..k {
                            let ih = ih0 + kh as i64;
                            if ih < 0 || ih >= h as i64 {
                                continue;
                            }
                            let xrow = (xb + ih as usize) * wd;
                            let wrow = wb + kh * k;
                            for kw in 0..k {
                                let iw = iw0 + kw as i64;
                                if iw < 0 || iw >= wd as i64 {
                                    continue;
                                }
                                gx[xrow + iw as usize] +=
                                    w.data[wrow + kw] * g;
                                gw[wrow + kw] +=
                                    x.data[xrow + iw as usize] * g;
                            }
                        }
                    }
                }
            }
        }
    }
    (
        Tensor::new(x.shape.clone(), gx),
        Tensor::new(w.shape.clone(), gw),
    )
}

/// (b, cin, cout, k, stride, groups, h, w) — the larger cases clear the
/// pool's MIN_PAR_WORK threshold so fan-out actually engages; the tiny
/// one exercises the inline path. The GEMM rewrite adds: k=1 above the
/// fan-out threshold (the direct no-im2col path), grouped stride-2, and
/// a group width that is not a multiple of the micro-tile (the gw
/// phase's row chunks then straddle group boundaries).
const CASES: [(usize, usize, usize, usize, usize, usize, usize, usize); 7] = [
    (4, 8, 8, 3, 1, 1, 12, 12),
    (2, 16, 16, 3, 2, 1, 16, 16),
    (4, 16, 16, 3, 1, 16, 16, 16), // depthwise
    (1, 3, 4, 1, 1, 1, 5, 5),      // tiny: inline path
    (4, 16, 16, 1, 1, 1, 16, 16),  // k=1 s1: direct path, above threshold
    (4, 16, 16, 3, 2, 2, 17, 17),  // grouped stride-2, odd spatial
    (4, 6, 9, 3, 1, 3, 16, 16),    // cpg_out=3: row chunks cross groups
];

#[test]
fn prop_parallel_conv2d_bitwise_matches_scalar_reference() {
    let _g = lock_pool();
    for seed in 0..6 {
        for &(b, cin, cout, k, stride, groups, h, w) in &CASES {
            let mut rng = Rng::new(7000 + seed);
            let mut x = randn(&mut rng, vec![b, cin, h, w], 1.0);
            let mut wt = randn(&mut rng, vec![cout, cin / groups, k, k], 0.3);
            inject_specials(&mut x);
            inject_specials(&mut wt);
            let want = conv2d_ref(&x, &wt, stride, groups);
            for nt in [1usize, 2, 8] {
                pool::set_threads(nt);
                let got = conv2d(&x, &wt, stride, groups);
                assert_eq!(got.shape, want.shape);
                assert_eq!(
                    bits_of(&got),
                    bits_of(&want),
                    "seed {seed} nt {nt} case {b}x{cin}->{cout} \
                     k{k} s{stride} g{groups}"
                );
            }
            pool::set_threads(0);
        }
    }
}

#[test]
fn prop_parallel_conv2d_bwd_bitwise_matches_scalar_reference() {
    let _g = lock_pool();
    for seed in 0..6 {
        for &(b, cin, cout, k, stride, groups, h, w) in &CASES {
            let mut rng = Rng::new(8000 + seed);
            let mut x = randn(&mut rng, vec![b, cin, h, w], 1.0);
            let mut wt = randn(&mut rng, vec![cout, cin / groups, k, k], 0.3);
            inject_specials(&mut x);
            inject_specials(&mut wt);
            let gout = {
                let probe = conv2d_ref(&x, &wt, stride, groups);
                let mut g = randn(&mut rng, probe.shape.clone(), 1.0);
                inject_specials(&mut g);
                g
            };
            let (gx_ref, gw_ref) =
                conv2d_bwd_ref(&x, &wt, stride, groups, &gout);
            for nt in [1usize, 2, 8] {
                pool::set_threads(nt);
                let (gx, gw) = conv2d_bwd(&x, &wt, stride, groups, &gout);
                assert_eq!(
                    bits_of(&gx),
                    bits_of(&gx_ref),
                    "gx seed {seed} nt {nt} case {b}x{cin}->{cout} \
                     k{k} s{stride} g{groups}"
                );
                assert_eq!(
                    bits_of(&gw),
                    bits_of(&gw_ref),
                    "gw seed {seed} nt {nt} case {b}x{cin}->{cout} \
                     k{k} s{stride} g{groups}"
                );
            }
            pool::set_threads(0);
        }
    }
}

/// Regression for the `g == 0.0` early-continue asymmetry: the scalar
/// reference skips zero output-gradients, the GEMM paths never do. The
/// skipped products are all ±0.0, and folding them in order is
/// bit-neutral (an `acc += p` chain starting from +0.0 can never hold
/// -0.0), so gradients stuffed with exact +0.0 and -0.0 — relu masks —
/// must still round-trip bit-identically through both the sequential and
/// the fanned-out backward at every thread count.
#[test]
fn conv2d_bwd_zero_gradient_skip_is_bit_neutral() {
    let _g = lock_pool();
    for &(b, cin, cout, k, stride, groups, h, w) in &CASES {
        let mut rng = Rng::new(4400);
        let mut x = randn(&mut rng, vec![b, cin, h, w], 1.0);
        let mut wt = randn(&mut rng, vec![cout, cin / groups, k, k], 0.3);
        inject_specials(&mut x);
        inject_specials(&mut wt);
        let probe = conv2d_ref(&x, &wt, stride, groups);
        // ~2/3 of the gradient exactly zero, alternating +0.0 / -0.0
        let mut gout = randn(&mut rng, probe.shape.clone(), 1.0);
        for (i, v) in gout.data.iter_mut().enumerate() {
            match i % 3 {
                0 => *v = 0.0,
                1 => *v = -0.0,
                _ => {}
            }
        }
        let (gx_ref, gw_ref) = conv2d_bwd_ref(&x, &wt, stride, groups, &gout);
        for nt in [1usize, 2, 8] {
            pool::set_threads(nt);
            let (gx, gw) = conv2d_bwd(&x, &wt, stride, groups, &gout);
            assert_eq!(
                bits_of(&gx),
                bits_of(&gx_ref),
                "gx zero-skip nt {nt} case {b}x{cin}->{cout} k{k} \
                 s{stride} g{groups}"
            );
            assert_eq!(
                bits_of(&gw),
                bits_of(&gw_ref),
                "gw zero-skip nt {nt} case {b}x{cin}->{cout} k{k} \
                 s{stride} g{groups}"
            );
        }
        // the fully-zero gradient: every output bit must be +0.0
        let zero = Tensor::zeros(probe.shape.clone());
        pool::set_threads(4);
        let (gx, gw) = conv2d_bwd(&x, &wt, stride, groups, &zero);
        assert!(gx.data.iter().all(|v| v.to_bits() == 0));
        assert!(gw.data.iter().all(|v| v.to_bits() == 0));
        pool::set_threads(0);
    }
}

/// Scalar reference for fc_fwd: the pre-GEMM loop.
fn fc_fwd_ref(x: &Tensor, w: &Tensor) -> Tensor {
    let (b, cin) = (x.shape[0], x.shape[1]);
    let cout = w.shape[0];
    let mut out = vec![0f32; b * cout];
    for bi in 0..b {
        for oc in 0..cout {
            let mut acc = 0f32;
            for i in 0..cin {
                acc += x.data[bi * cin + i] * w.data[oc * cin + i];
            }
            out[bi * cout + oc] = acc;
        }
    }
    Tensor::new(vec![b, cout], out)
}

/// Scalar reference for fc_bwd: the fused pre-GEMM loop.
fn fc_bwd_ref(x: &Tensor, w: &Tensor, gout: &Tensor) -> (Tensor, Tensor) {
    let (b, cin) = (x.shape[0], x.shape[1]);
    let cout = w.shape[0];
    let mut gx = vec![0f32; b * cin];
    let mut gw = vec![0f32; cout * cin];
    for bi in 0..b {
        for oc in 0..cout {
            let g = gout.data[bi * cout + oc];
            for i in 0..cin {
                gx[bi * cin + i] += g * w.data[oc * cin + i];
                gw[oc * cin + i] += g * x.data[bi * cin + i];
            }
        }
    }
    (
        Tensor::new(x.shape.clone(), gx),
        Tensor::new(w.shape.clone(), gw),
    )
}

#[test]
fn prop_fc_gemm_path_bitwise_matches_scalar_reference() {
    let _g = lock_pool();
    for seed in 0..4 {
        for &(b, cin, cout) in
            &[(32usize, 12usize, 8usize), (5, 7, 3), (1, 4, 2), (64, 48, 33)]
        {
            let mut rng = Rng::new(5100 + seed);
            let mut x = randn(&mut rng, vec![b, cin], 1.0);
            let mut w = randn(&mut rng, vec![cout, cin], 0.3);
            let mut gout = randn(&mut rng, vec![b, cout], 1.0);
            inject_specials(&mut x);
            inject_specials(&mut w);
            inject_specials(&mut gout);
            let want = fc_fwd_ref(&x, &w);
            let (gx_ref, gw_ref) = fc_bwd_ref(&x, &w, &gout);
            for nt in [1usize, 2, 8] {
                pool::set_threads(nt);
                assert_eq!(
                    bits_of(&fc_fwd(&x, &w)),
                    bits_of(&want),
                    "fc fwd seed {seed} nt {nt} {b}x{cin}->{cout}"
                );
                let (gx, gw) = fc_bwd(&x, &w, &gout);
                assert_eq!(bits_of(&gx), bits_of(&gx_ref), "fc gx nt {nt}");
                assert_eq!(bits_of(&gw), bits_of(&gw_ref), "fc gw nt {nt}");
            }
            pool::set_threads(0);
        }
    }
}

/// The zero-alloc-scratch guarantee: once the kernels are warm, repeated
/// steps serve every im2col / packed-panel / shared-slab request from
/// the recycling arenas — the allocation counter must not move. (This
/// test owns the counters: every test in this binary serializes on
/// POOL_LOCK, and no other test binary shares the process.)
#[test]
fn warm_kernels_do_zero_scratch_allocations() {
    let _g = lock_pool();
    let mut rng = Rng::new(99);
    let x = randn(&mut rng, vec![8, 16, 16, 16], 1.0);
    let wt = randn(&mut rng, vec![16, 16, 3, 3], 0.3);
    let xf = randn(&mut rng, vec![32, 48], 1.0);
    let wf = randn(&mut rng, vec![16, 48], 0.3);
    let gf = randn(&mut rng, vec![32, 16], 1.0);
    let gout = {
        let probe = conv2d(&x, &wt, 1, 1);
        randn(&mut rng, probe.shape.clone(), 1.0)
    };
    for nt in [1usize, 4] {
        pool::set_threads(nt);
        let step = || {
            std::hint::black_box(conv2d(&x, &wt, 1, 1));
            std::hint::black_box(conv2d_bwd(&x, &wt, 1, 1, &gout));
            std::hint::black_box(fc_fwd(&xf, &wf));
            std::hint::black_box(fc_bwd(&xf, &wf, &gf));
        };
        for _ in 0..3 {
            step(); // warm the arenas (workers recycle scratch sets)
        }
        let (allocs_before, reuses_before) = pool::scratch_counters();
        for _ in 0..5 {
            step();
        }
        let (allocs_after, reuses_after) = pool::scratch_counters();
        assert_eq!(
            allocs_after, allocs_before,
            "steady-state kernels allocated scratch at {nt} threads"
        );
        assert!(
            reuses_after > reuses_before,
            "scratch reuse counter did not advance at {nt} threads"
        );
    }
    pool::set_threads(0);
}

/// The model-level executables (eval_fwd, act_obs via init_act_steps,
/// fim) must produce bit-identical outputs at 1 vs 4 threads.
#[test]
fn model_executables_bitwise_invariant_across_thread_counts() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().expect("synthetic environment");
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let train = env.train_set().unwrap();
    let test = env.test_set().unwrap();
    let calib = env.calib(&train, 64, 9);
    let bits = BitConfig::uniform(model, 4, Some(8), true);

    let mut runs = Vec::new();
    for nt in [1usize, 4] {
        pool::set_threads(nt);
        let fim = cal.fim_pass("block", &calib, &ws, &bs).unwrap();
        let steps = cal.init_act_steps(&calib, &ws, &bs, &bits, 2).unwrap();
        let acc =
            accuracy(&env.rt, model, &EvalParams::fp(model, &ws, &bs), &test)
                .unwrap();
        runs.push((
            fim.iter().map(bits_of).collect::<Vec<_>>(),
            steps.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            acc.to_bits(),
        ));
    }
    pool::set_threads(0);
    assert_eq!(runs[0], runs[1], "fim/act_obs/eval depend on thread count");
}

/// Full Algorithm 1 must be bit-identical at 1 vs 4 threads: identical
/// per-unit loss curves, committed weights and learned act steps.
#[test]
fn reconstruction_bitwise_invariant_across_thread_counts() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().expect("synthetic environment");
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 32, 3);
    let bits = BitConfig::uniform(model, 4, Some(8), true);
    let cfg = ReconConfig {
        iters: 12,
        batch: 32,
        seed: 0,
        ..ReconConfig::default()
    };

    let mut runs = Vec::new();
    for nt in [1usize, 4] {
        pool::set_threads(nt);
        let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
        runs.push((
            qm.reports
                .iter()
                .map(|r| (r.initial_loss.to_bits(), r.final_loss.to_bits()))
                .collect::<Vec<_>>(),
            qm.weights.iter().map(bits_of).collect::<Vec<_>>(),
            qm.act_steps.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        ));
    }
    pool::set_threads(0);
    assert_eq!(runs[0], runs[1], "calibration depends on thread count");
}
