//! Parallel-execution parity properties: the pool-backed kernels and the
//! calibration pipeline must be **bit-identical** to scalar references at
//! 1/2/8 threads — the determinism contract documented in `util::pool`
//! and the README threading section.
//!
//! `pool::set_threads` is process-global, so these tests can interleave
//! with the rest of the suite; that is exactly the property under test —
//! results must not depend on the pool size in effect at any moment.

use std::sync::Mutex;

use brecq::coordinator::Env;
use brecq::eval::{accuracy, EvalParams};
use brecq::recon::{BitConfig, Calibrator, ReconConfig};
use brecq::runtime::native::{conv2d, conv2d_bwd};
use brecq::tensor::Tensor;
use brecq::util::pool;
use brecq::util::rng::Rng;

/// `pool::set_threads` is process-global and libtest runs tests
/// concurrently: serialize every test in this binary so the "run at N
/// threads" phases really execute at N threads (otherwise a sibling test
/// could flip the pool size mid-run and the invariance assertions would
/// compare two same-thread-count runs).
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock_pool() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn randn(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
}

fn bits_of(t: &Tensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

/// TF/XLA 'SAME' padding (mirrors the private helper in runtime::native).
fn same_pads(h: usize, k: usize, s: usize) -> (usize, i64) {
    let out = (h + s - 1) / s;
    let total = ((out - 1) * s + k).saturating_sub(h);
    (out, (total / 2) as i64)
}

/// Scalar reference convolution: the fused single-threaded loop the
/// parallel kernel must reproduce bit-for-bit.
fn conv2d_ref(x: &Tensor, w: &Tensor, stride: usize, groups: usize)
    -> Tensor {
    let (b, cin, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cout, cpg_in, k) = (w.shape[0], w.shape[1], w.shape[2]);
    let cpg_out = cout / groups;
    let (ho, pad_h) = same_pads(h, k, stride);
    let (wo, pad_w) = same_pads(wd, k, stride);
    let mut out = vec![0f32; b * cout * ho * wo];
    for bi in 0..b {
        for oc in 0..cout {
            let gi = oc / cpg_out;
            let wbase = oc * cpg_in * k * k;
            for oh in 0..ho {
                let ih0 = (oh * stride) as i64 - pad_h;
                for ow in 0..wo {
                    let iw0 = (ow * stride) as i64 - pad_w;
                    let mut acc = 0f32;
                    for ic in 0..cpg_in {
                        let ci = gi * cpg_in + ic;
                        let xb = (bi * cin + ci) * h;
                        let wb = wbase + ic * k * k;
                        for kh in 0..k {
                            let ih = ih0 + kh as i64;
                            if ih < 0 || ih >= h as i64 {
                                continue;
                            }
                            let xrow = (xb + ih as usize) * wd;
                            let wrow = wb + kh * k;
                            for kw in 0..k {
                                let iw = iw0 + kw as i64;
                                if iw < 0 || iw >= wd as i64 {
                                    continue;
                                }
                                acc += x.data[xrow + iw as usize]
                                    * w.data[wrow + kw];
                            }
                        }
                    }
                    out[((bi * cout + oc) * ho + oh) * wo + ow] = acc;
                }
            }
        }
    }
    Tensor::new(vec![b, cout, ho, wo], out)
}

/// Scalar reference backward: the fused loop updating both grads in one
/// traversal (the pre-pool implementation).
fn conv2d_bwd_ref(
    x: &Tensor,
    w: &Tensor,
    stride: usize,
    groups: usize,
    gout: &Tensor,
) -> (Tensor, Tensor) {
    let (b, cin, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (cout, cpg_in, k) = (w.shape[0], w.shape[1], w.shape[2]);
    let cpg_out = cout / groups;
    let (ho, pad_h) = same_pads(h, k, stride);
    let (wo, pad_w) = same_pads(wd, k, stride);
    let mut gx = vec![0f32; x.data.len()];
    let mut gw = vec![0f32; w.data.len()];
    for bi in 0..b {
        for oc in 0..cout {
            let gi = oc / cpg_out;
            let wbase = oc * cpg_in * k * k;
            for oh in 0..ho {
                let ih0 = (oh * stride) as i64 - pad_h;
                for ow in 0..wo {
                    let iw0 = (ow * stride) as i64 - pad_w;
                    let g = gout.data[((bi * cout + oc) * ho + oh) * wo + ow];
                    if g == 0.0 {
                        continue;
                    }
                    for ic in 0..cpg_in {
                        let ci = gi * cpg_in + ic;
                        let xb = (bi * cin + ci) * h;
                        let wb = wbase + ic * k * k;
                        for kh in 0..k {
                            let ih = ih0 + kh as i64;
                            if ih < 0 || ih >= h as i64 {
                                continue;
                            }
                            let xrow = (xb + ih as usize) * wd;
                            let wrow = wb + kh * k;
                            for kw in 0..k {
                                let iw = iw0 + kw as i64;
                                if iw < 0 || iw >= wd as i64 {
                                    continue;
                                }
                                gx[xrow + iw as usize] +=
                                    w.data[wrow + kw] * g;
                                gw[wrow + kw] +=
                                    x.data[xrow + iw as usize] * g;
                            }
                        }
                    }
                }
            }
        }
    }
    (
        Tensor::new(x.shape.clone(), gx),
        Tensor::new(w.shape.clone(), gw),
    )
}

/// (b, cin, cout, k, stride, groups, h, w) — the larger cases clear the
/// pool's MIN_PAR_WORK threshold so fan-out actually engages; the tiny
/// one exercises the inline path.
const CASES: [(usize, usize, usize, usize, usize, usize, usize, usize); 4] = [
    (4, 8, 8, 3, 1, 1, 12, 12),
    (2, 16, 16, 3, 2, 1, 16, 16),
    (4, 16, 16, 3, 1, 16, 16, 16), // depthwise
    (1, 3, 4, 1, 1, 1, 5, 5),      // tiny: inline path
];

#[test]
fn prop_parallel_conv2d_bitwise_matches_scalar_reference() {
    let _g = lock_pool();
    for seed in 0..6 {
        for &(b, cin, cout, k, stride, groups, h, w) in &CASES {
            let mut rng = Rng::new(7000 + seed);
            let x = randn(&mut rng, vec![b, cin, h, w], 1.0);
            let wt = randn(&mut rng, vec![cout, cin / groups, k, k], 0.3);
            let want = conv2d_ref(&x, &wt, stride, groups);
            for nt in [1usize, 2, 8] {
                pool::set_threads(nt);
                let got = conv2d(&x, &wt, stride, groups);
                assert_eq!(got.shape, want.shape);
                assert_eq!(
                    bits_of(&got),
                    bits_of(&want),
                    "seed {seed} nt {nt} case {b}x{cin}->{cout} \
                     k{k} s{stride} g{groups}"
                );
            }
            pool::set_threads(0);
        }
    }
}

#[test]
fn prop_parallel_conv2d_bwd_bitwise_matches_scalar_reference() {
    let _g = lock_pool();
    for seed in 0..6 {
        for &(b, cin, cout, k, stride, groups, h, w) in &CASES {
            let mut rng = Rng::new(8000 + seed);
            let x = randn(&mut rng, vec![b, cin, h, w], 1.0);
            let wt = randn(&mut rng, vec![cout, cin / groups, k, k], 0.3);
            let gout = {
                let probe = conv2d_ref(&x, &wt, stride, groups);
                randn(&mut rng, probe.shape.clone(), 1.0)
            };
            let (gx_ref, gw_ref) =
                conv2d_bwd_ref(&x, &wt, stride, groups, &gout);
            for nt in [1usize, 2, 8] {
                pool::set_threads(nt);
                let (gx, gw) = conv2d_bwd(&x, &wt, stride, groups, &gout);
                assert_eq!(
                    bits_of(&gx),
                    bits_of(&gx_ref),
                    "gx seed {seed} nt {nt} case {b}x{cin}->{cout} \
                     k{k} s{stride} g{groups}"
                );
                assert_eq!(
                    bits_of(&gw),
                    bits_of(&gw_ref),
                    "gw seed {seed} nt {nt} case {b}x{cin}->{cout} \
                     k{k} s{stride} g{groups}"
                );
            }
            pool::set_threads(0);
        }
    }
}

/// The model-level executables (eval_fwd, act_obs via init_act_steps,
/// fim) must produce bit-identical outputs at 1 vs 4 threads.
#[test]
fn model_executables_bitwise_invariant_across_thread_counts() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().expect("synthetic environment");
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let train = env.train_set().unwrap();
    let test = env.test_set().unwrap();
    let calib = env.calib(&train, 64, 9);
    let bits = BitConfig::uniform(model, 4, Some(8), true);

    let mut runs = Vec::new();
    for nt in [1usize, 4] {
        pool::set_threads(nt);
        let fim = cal.fim_pass("block", &calib, &ws, &bs).unwrap();
        let steps = cal.init_act_steps(&calib, &ws, &bs, &bits, 2).unwrap();
        let acc =
            accuracy(&env.rt, model, &EvalParams::fp(model, &ws, &bs), &test)
                .unwrap();
        runs.push((
            fim.iter().map(bits_of).collect::<Vec<_>>(),
            steps.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            acc.to_bits(),
        ));
    }
    pool::set_threads(0);
    assert_eq!(runs[0], runs[1], "fim/act_obs/eval depend on thread count");
}

/// Full Algorithm 1 must be bit-identical at 1 vs 4 threads: identical
/// per-unit loss curves, committed weights and learned act steps.
#[test]
fn reconstruction_bitwise_invariant_across_thread_counts() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().expect("synthetic environment");
    let model = env.model("resnet_s");
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 32, 3);
    let bits = BitConfig::uniform(model, 4, Some(8), true);
    let cfg = ReconConfig {
        iters: 12,
        batch: 32,
        seed: 0,
        ..ReconConfig::default()
    };

    let mut runs = Vec::new();
    for nt in [1usize, 4] {
        pool::set_threads(nt);
        let qm = cal.calibrate(&calib, &bits, &cfg).unwrap();
        runs.push((
            qm.reports
                .iter()
                .map(|r| (r.initial_loss.to_bits(), r.final_loss.to_bits()))
                .collect::<Vec<_>>(),
            qm.weights.iter().map(bits_of).collect::<Vec<_>>(),
            qm.act_steps.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        ));
    }
    pool::set_threads(0);
    assert_eq!(runs[0], runs[1], "calibration depends on thread count");
}
