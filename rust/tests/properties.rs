//! Property-based tests (hand-rolled generators over util::rng — proptest
//! is unavailable offline). Each property runs across many random seeds;
//! failures print the seed for replay.

use brecq::quant::{
    act_bounds, mse_steps_per_channel, quantize_nearest, rect_sigmoid,
    rect_sigmoid_inv, round_quant, weight_bounds, AdaRoundState,
};
use brecq::runtime::native;
use brecq::tensor::Tensor;
use brecq::util::json::Json;
use brecq::util::rng::Rng;

fn randn(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
}

#[test]
fn prop_nearest_quant_idempotent() {
    for seed in 0..30 {
        let mut rng = Rng::new(seed);
        let c = 1 + rng.below(8);
        let k = 1 + rng.below(64);
        let bits = [2, 3, 4, 8][rng.below(4)];
        let scale = 0.1 + rng.f32();
        let w = randn(&mut rng, vec![c, k], scale);
        let steps = mse_steps_per_channel(&w, bits);
        let q1 = quantize_nearest(&w, &steps, bits);
        let q2 = quantize_nearest(&q1, &steps, bits);
        for i in 0..q1.data.len() {
            assert!((q1.data[i] - q2.data[i]).abs() < 1e-5,
                    "seed {seed} idx {i}");
        }
    }
}

#[test]
fn prop_nearest_quant_error_bounded_by_half_step_or_clip() {
    for seed in 0..30 {
        let mut rng = Rng::new(1000 + seed);
        let c = 1 + rng.below(4);
        let k = 8 + rng.below(64);
        let bits = [2, 4, 8][rng.below(3)];
        let (n, p) = weight_bounds(bits);
        let w = randn(&mut rng, vec![c, k], 0.5);
        let steps = mse_steps_per_channel(&w, bits);
        let q = quantize_nearest(&w, &steps, bits);
        let inner = w.inner();
        for ch in 0..c {
            let s = steps[ch];
            for i in ch * inner..(ch + 1) * inner {
                let clipped = (w.data[i] / s) < n || (w.data[i] / s) > p;
                if !clipped {
                    assert!((q.data[i] - w.data[i]).abs() <= s * 0.5 + 1e-6,
                            "seed {seed}: err {} > s/2 {}",
                            (q.data[i] - w.data[i]).abs(), s * 0.5);
                }
            }
        }
    }
}

#[test]
fn prop_adaround_commit_on_grid_and_within_one_step() {
    for seed in 0..30 {
        let mut rng = Rng::new(2000 + seed);
        let c = 1 + rng.below(6);
        let k = 4 + rng.below(40);
        let bits = [2, 3, 4][rng.below(3)];
        let (n, p) = weight_bounds(bits);
        let w = randn(&mut rng, vec![c, k], 0.3);
        let steps = mse_steps_per_channel(&w, bits);
        let mut st = AdaRoundState::init(&w, &steps, bits);
        // random v perturbation (mid-optimization state)
        for v in st.v.data.iter_mut() {
            *v += rng.gauss() as f32 * 2.0;
        }
        let q = st.commit(&w);
        let nearest = quantize_nearest(&w, &steps, bits);
        let inner = w.inner();
        for ch in 0..c {
            let s = steps[ch];
            for i in ch * inner..(ch + 1) * inner {
                let g = q.data[i] / s;
                assert!((g - g.round()).abs() < 1e-3, "grid seed {seed}");
                assert!(g.round() >= n && g.round() <= p, "range seed {seed}");
                assert!((q.data[i] - nearest.data[i]).abs() <= s + 1e-5,
                        "one-step seed {seed}");
            }
        }
    }
}

#[test]
fn prop_rect_sigmoid_inverse_roundtrips() {
    for seed in 0..50 {
        let mut rng = Rng::new(3000 + seed);
        let h = 0.02 + 0.96 * rng.f32();
        let v = rect_sigmoid_inv(h);
        assert!((rect_sigmoid(v) - h).abs() < 1e-4, "seed {seed} h {h}");
    }
}

#[test]
fn prop_bounds_consistent() {
    for bits in 2..=8 {
        let (n, p) = weight_bounds(bits);
        assert_eq!(p - n + 1.0, 2f32.powi(bits as i32));
        let (un, up) = act_bounds(bits, false);
        assert_eq!(un, 0.0);
        assert_eq!(up - un + 1.0, 2f32.powi(bits as i32));
        assert_eq!(act_bounds(bits, true), (n, p));
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.gauss() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            ['a', '"', '\\', '\n', 'µ', '7', ' '][rng.below(7)]
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5))
                .map(|_| gen(rng, depth + 1))
                .collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..200 {
        let mut rng = Rng::new(4000 + seed);
        let v = gen(&mut rng, 0);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_tensor_slice_stack_partition() {
    for seed in 0..30 {
        let mut rng = Rng::new(5000 + seed);
        let rows = 2 + rng.below(20);
        let inner = 1 + rng.below(16);
        let t = randn(&mut rng, vec![rows, inner], 1.0);
        // random partition of rows
        let cut = 1 + rng.below(rows - 1);
        let joined = Tensor::stack0(&[t.slice0(0, cut),
                                      t.slice0(cut, rows - cut)]);
        assert_eq!(joined, t, "seed {seed}");
    }
}

#[test]
fn prop_adam_descends_random_quadratics() {
    use brecq::optim::Adam;
    for seed in 0..10 {
        let mut rng = Rng::new(6000 + seed);
        let n = 1 + rng.below(16);
        let target = randn(&mut rng, vec![n], 3.0);
        let scale: Vec<f32> =
            (0..n).map(|_| 0.5 + 2.0 * rng.f32()).collect();
        let mut x = Tensor::zeros(vec![n]);
        let mut opt = Adam::new(0.15, &[n]);
        let loss = |x: &Tensor| -> f64 {
            x.data
                .iter()
                .zip(&target.data)
                .zip(&scale)
                .map(|((a, b), s)| (s * (a - b)) as f64 * ((a - b) as f64))
                .sum()
        };
        let l0 = loss(&x);
        for _ in 0..600 {
            let g = Tensor::new(
                vec![n],
                x.data
                    .iter()
                    .zip(&target.data)
                    .zip(&scale)
                    .map(|((a, b), s)| 2.0 * s * (a - b))
                    .collect(),
            );
            opt.step(&mut [&mut x], &[&g]);
        }
        assert!(loss(&x) < l0 * 0.01, "seed {seed}: {} vs {}", loss(&x), l0);
    }
}

// ------------------------------------------------------------------
// Native-backend kernel properties: the runtime::native ports must agree
// with the quant.rs host-side primitives to 1e-5 on randomized inputs.
// ------------------------------------------------------------------

#[test]
fn prop_native_rect_sigmoid_matches_host() {
    for seed in 0..50 {
        let mut rng = Rng::new(9000 + seed);
        let v = (rng.gauss() * 4.0) as f32;
        assert!(
            (native::rect_sigmoid(v) - rect_sigmoid(v)).abs() < 1e-5,
            "seed {seed} v {v}"
        );
        // inverse round-trip through the native forward
        let h = 0.02 + 0.96 * rng.f32();
        let vi = rect_sigmoid_inv(h);
        assert!(
            (native::rect_sigmoid(vi) - h).abs() < 1e-4,
            "seed {seed} h {h}"
        );
    }
}

#[test]
fn prop_native_round_ste_matches_quantize_nearest() {
    // native round_ste with per-channel MSE steps must reproduce the
    // host-side quantize_nearest elementwise
    for seed in 0..30 {
        let mut rng = Rng::new(9100 + seed);
        let c = 1 + rng.below(6);
        let k = 4 + rng.below(48);
        let bits = [2, 3, 4, 8][rng.below(4)];
        let (n, p) = weight_bounds(bits);
        let w = randn(&mut rng, vec![c, k], 0.2 + rng.f32());
        let steps = mse_steps_per_channel(&w, bits);
        let q = quantize_nearest(&w, &steps, bits);
        let inner = w.inner();
        for ch in 0..c {
            for i in ch * inner..(ch + 1) * inner {
                let native_q = native::round_ste(w.data[i], steps[ch], n, p);
                assert!(
                    (native_q - q.data[i]).abs() < 1e-5,
                    "seed {seed} ch {ch} i {i}"
                );
                // and both agree with the scalar host primitive
                let host_q = round_quant(w.data[i], steps[ch], n, p);
                assert!((native_q - host_q).abs() < 1e-5);
            }
        }
    }
}

#[test]
fn prop_native_lsq_grad_piecewise_cases() {
    // Eq. 18: dxhat/ds = qmin below, qmax above, round(x/s)-x/s inside;
    // dxhat/dx = STE indicator of the clip range
    for seed in 0..50 {
        let mut rng = Rng::new(9200 + seed);
        let bits = [2, 4, 8][rng.below(3)];
        let signed = rng.f64() < 0.5;
        let (qmin, qmax) = act_bounds(bits, signed);
        let step = 0.05 + rng.f32() * 0.5;
        let gout = (rng.gauss() as f32) + 0.1;

        // below the range
        let x_lo = (qmin - 1.5) * step;
        let (gx, gs) = native::lsq_grads(x_lo, step, qmin, qmax, gout);
        assert_eq!(gx, 0.0, "seed {seed}");
        assert!((gs - gout * qmin).abs() < 1e-5, "seed {seed}");

        // above the range
        let x_hi = (qmax + 1.5) * step;
        let (gx, gs) = native::lsq_grads(x_hi, step, qmin, qmax, gout);
        assert_eq!(gx, 0.0, "seed {seed}");
        assert!((gs - gout * qmax).abs() < 1e-5, "seed {seed}");

        // strictly interior, away from the rounding boundary
        let mid = (qmin + qmax) / 2.0;
        let frac = 0.1 + 0.3 * rng.f32(); // keep |frac - 0.5| >= 0.1
        let xs = mid.floor() + frac;
        if xs > qmin && xs < qmax {
            let x = xs * step;
            let (gx, gs) = native::lsq_grads(x, step, qmin, qmax, gout);
            assert!((gx - gout).abs() < 1e-6, "seed {seed}");
            let expect = gout * (xs.round() - xs);
            assert!((gs - expect).abs() < 1e-4, "seed {seed}");
            // forward consistency at the same point
            let fwd = native::lsq(x, step, qmin, qmax);
            let host = round_quant(x, step, qmin, qmax);
            assert!((fwd - host).abs() < 1e-5, "seed {seed}");
        }
    }
}

#[test]
fn prop_native_adaround_hard_commit_matches_nearest_when_saturated() {
    // when h(v) saturates toward the nearest-rounding direction, the hard
    // commit IS nearest rounding — elementwise and through AdaRoundState
    for seed in 0..30 {
        let mut rng = Rng::new(9300 + seed);
        let c = 1 + rng.below(4);
        let k = 4 + rng.below(32);
        let bits = [2, 3, 4][rng.below(3)];
        let (n, p) = weight_bounds(bits);
        let w = randn(&mut rng, vec![c, k], 0.4);
        let steps = mse_steps_per_channel(&w, bits);
        let mut st = AdaRoundState::init(&w, &steps, bits);
        let inner = w.inner();
        for ch in 0..c {
            let s = steps[ch];
            for i in ch * inner..(ch + 1) * inner {
                let frac = w.data[i] / s - (w.data[i] / s).floor();
                // saturate h to 0/1 toward the nearest grid point
                st.v.data[i] = if frac >= 0.5 { 10.0 } else { -10.0 };
                let hard =
                    native::adaround_hard(w.data[i], s, st.v.data[i], n, p);
                let nearest = round_quant(w.data[i], s, n, p);
                assert!(
                    (hard - nearest).abs() < 1e-5,
                    "seed {seed}: {hard} vs {nearest}"
                );
            }
        }
        let committed = st.commit(&w);
        let nearest = quantize_nearest(&w, &steps, bits);
        for i in 0..committed.data.len() {
            assert!(
                (committed.data[i] - nearest.data[i]).abs() < 1e-5,
                "seed {seed} idx {i}"
            );
        }
    }
}

#[test]
fn prop_native_adaround_soft_matches_host_formula() {
    // the native soft fake-quant equals s*clip(floor(w/s)+h(v), n, p) with
    // the host rect_sigmoid
    for seed in 0..50 {
        let mut rng = Rng::new(9400 + seed);
        let bits = [2, 4][rng.below(2)];
        let (n, p) = weight_bounds(bits);
        let w = rng.gauss() as f32;
        let s = 0.05 + rng.f32() * 0.3;
        let v = (rng.gauss() * 3.0) as f32;
        let expect = s * ((w / s).floor() + rect_sigmoid(v)).clamp(n, p);
        assert!(
            (native::adaround(w, s, v, n, p) - expect).abs() < 1e-5,
            "seed {seed}"
        );
    }
}

#[test]
fn prop_rng_streams_independent() {
    // forked streams must not correlate trivially
    let mut a = Rng::new(7);
    let mut b = a.fork();
    let mut same = 0;
    for _ in 0..1000 {
        if (a.f64() < 0.5) == (b.f64() < 0.5) {
            same += 1;
        }
    }
    assert!((400..600).contains(&same), "{same}");
}
