//! Property-based tests (hand-rolled generators over util::rng — proptest
//! is unavailable offline). Each property runs across many random seeds;
//! failures print the seed for replay.

use brecq::quant::{
    act_bounds, mse_steps_per_channel, quantize_nearest, rect_sigmoid,
    rect_sigmoid_inv, weight_bounds, AdaRoundState,
};
use brecq::tensor::Tensor;
use brecq::util::json::Json;
use brecq::util::rng::Rng;

fn randn(rng: &mut Rng, shape: Vec<usize>, scale: f32) -> Tensor {
    let n: usize = shape.iter().product();
    Tensor::new(shape, (0..n).map(|_| rng.gauss() as f32 * scale).collect())
}

#[test]
fn prop_nearest_quant_idempotent() {
    for seed in 0..30 {
        let mut rng = Rng::new(seed);
        let c = 1 + rng.below(8);
        let k = 1 + rng.below(64);
        let bits = [2, 3, 4, 8][rng.below(4)];
        let scale = 0.1 + rng.f32();
        let w = randn(&mut rng, vec![c, k], scale);
        let steps = mse_steps_per_channel(&w, bits);
        let q1 = quantize_nearest(&w, &steps, bits);
        let q2 = quantize_nearest(&q1, &steps, bits);
        for i in 0..q1.data.len() {
            assert!((q1.data[i] - q2.data[i]).abs() < 1e-5,
                    "seed {seed} idx {i}");
        }
    }
}

#[test]
fn prop_nearest_quant_error_bounded_by_half_step_or_clip() {
    for seed in 0..30 {
        let mut rng = Rng::new(1000 + seed);
        let c = 1 + rng.below(4);
        let k = 8 + rng.below(64);
        let bits = [2, 4, 8][rng.below(3)];
        let (n, p) = weight_bounds(bits);
        let w = randn(&mut rng, vec![c, k], 0.5);
        let steps = mse_steps_per_channel(&w, bits);
        let q = quantize_nearest(&w, &steps, bits);
        let inner = w.inner();
        for ch in 0..c {
            let s = steps[ch];
            for i in ch * inner..(ch + 1) * inner {
                let clipped = (w.data[i] / s) < n || (w.data[i] / s) > p;
                if !clipped {
                    assert!((q.data[i] - w.data[i]).abs() <= s * 0.5 + 1e-6,
                            "seed {seed}: err {} > s/2 {}",
                            (q.data[i] - w.data[i]).abs(), s * 0.5);
                }
            }
        }
    }
}

#[test]
fn prop_adaround_commit_on_grid_and_within_one_step() {
    for seed in 0..30 {
        let mut rng = Rng::new(2000 + seed);
        let c = 1 + rng.below(6);
        let k = 4 + rng.below(40);
        let bits = [2, 3, 4][rng.below(3)];
        let (n, p) = weight_bounds(bits);
        let w = randn(&mut rng, vec![c, k], 0.3);
        let steps = mse_steps_per_channel(&w, bits);
        let mut st = AdaRoundState::init(&w, &steps, bits);
        // random v perturbation (mid-optimization state)
        for v in st.v.data.iter_mut() {
            *v += rng.gauss() as f32 * 2.0;
        }
        let q = st.commit(&w);
        let nearest = quantize_nearest(&w, &steps, bits);
        let inner = w.inner();
        for ch in 0..c {
            let s = steps[ch];
            for i in ch * inner..(ch + 1) * inner {
                let g = q.data[i] / s;
                assert!((g - g.round()).abs() < 1e-3, "grid seed {seed}");
                assert!(g.round() >= n && g.round() <= p, "range seed {seed}");
                assert!((q.data[i] - nearest.data[i]).abs() <= s + 1e-5,
                        "one-step seed {seed}");
            }
        }
    }
}

#[test]
fn prop_rect_sigmoid_inverse_roundtrips() {
    for seed in 0..50 {
        let mut rng = Rng::new(3000 + seed);
        let h = 0.02 + 0.96 * rng.f32();
        let v = rect_sigmoid_inv(h);
        assert!((rect_sigmoid(v) - h).abs() < 1e-4, "seed {seed} h {h}");
    }
}

#[test]
fn prop_bounds_consistent() {
    for bits in 2..=8 {
        let (n, p) = weight_bounds(bits);
        assert_eq!(p - n + 1.0, 2f32.powi(bits as i32));
        let (un, up) = act_bounds(bits, false);
        assert_eq!(un, 0.0);
        assert_eq!(up - un + 1.0, 2f32.powi(bits as i32));
        assert_eq!(act_bounds(bits, true), (n, p));
    }
}

#[test]
fn prop_json_roundtrip_fuzz() {
    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 3 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.f64() < 0.5),
            2 => Json::Num((rng.gauss() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let n = rng.below(12);
                Json::Str(
                    (0..n)
                        .map(|_| {
                            ['a', '"', '\\', '\n', 'µ', '7', ' '][rng.below(7)]
                        })
                        .collect(),
                )
            }
            4 => Json::Arr((0..rng.below(5))
                .map(|_| gen(rng, depth + 1))
                .collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    for seed in 0..200 {
        let mut rng = Rng::new(4000 + seed);
        let v = gen(&mut rng, 0);
        let text = v.to_string();
        let back = Json::parse(&text)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}\n{text}"));
        assert_eq!(back, v, "seed {seed}");
    }
}

#[test]
fn prop_tensor_slice_stack_partition() {
    for seed in 0..30 {
        let mut rng = Rng::new(5000 + seed);
        let rows = 2 + rng.below(20);
        let inner = 1 + rng.below(16);
        let t = randn(&mut rng, vec![rows, inner], 1.0);
        // random partition of rows
        let cut = 1 + rng.below(rows - 1);
        let joined = Tensor::stack0(&[t.slice0(0, cut),
                                      t.slice0(cut, rows - cut)]);
        assert_eq!(joined, t, "seed {seed}");
    }
}

#[test]
fn prop_adam_descends_random_quadratics() {
    use brecq::optim::Adam;
    for seed in 0..10 {
        let mut rng = Rng::new(6000 + seed);
        let n = 1 + rng.below(16);
        let target = randn(&mut rng, vec![n], 3.0);
        let scale: Vec<f32> =
            (0..n).map(|_| 0.5 + 2.0 * rng.f32()).collect();
        let mut x = Tensor::zeros(vec![n]);
        let mut opt = Adam::new(0.15, &[n]);
        let loss = |x: &Tensor| -> f64 {
            x.data
                .iter()
                .zip(&target.data)
                .zip(&scale)
                .map(|((a, b), s)| (s * (a - b)) as f64 * ((a - b) as f64))
                .sum()
        };
        let l0 = loss(&x);
        for _ in 0..600 {
            let g = Tensor::new(
                vec![n],
                x.data
                    .iter()
                    .zip(&target.data)
                    .zip(&scale)
                    .map(|((a, b), s)| 2.0 * s * (a - b))
                    .collect(),
            );
            opt.step(&mut [&mut x], &[&g]);
        }
        assert!(loss(&x) < l0 * 0.01, "seed {seed}: {} vs {}", loss(&x), l0);
    }
}

#[test]
fn prop_rng_streams_independent() {
    // forked streams must not correlate trivially
    let mut a = Rng::new(7);
    let mut b = a.fork();
    let mut same = 0;
    for _ in 0..1000 {
        if (a.f64() < 0.5) == (b.f64() < 0.5) {
            same += 1;
        }
    }
    assert!((400..600).contains(&same), "{same}");
}
