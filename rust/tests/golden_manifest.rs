//! Golden-fixture test: a small hand-written manifest under
//! tests/fixtures/ must parse through `model::Manifest` exactly as the
//! schema documents, and the JSON layer must round-trip it byte-equivalent
//! at the value level.

use std::path::Path;

use brecq::model::Manifest;
use brecq::runtime::parse_sigs;
use brecq::util::json::Json;

fn fixture_dir() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures")
}

#[test]
fn golden_manifest_parses() {
    let mf = Manifest::load(&fixture_dir()).expect("fixture manifest");
    assert_eq!(mf.calib_batch, 4);
    assert_eq!(mf.dataset.img, 6);
    assert_eq!(mf.dataset.classes, 3);
    assert_eq!(mf.dataset.train_n, 24);
    assert_eq!(mf.dataset.mean, vec![0.5, 0.5, 0.5]);

    let toy = mf.model("toy");
    assert!((toy.fp_acc - 0.875).abs() < 1e-12);
    assert_eq!(toy.layers.len(), 2);
    assert_eq!(toy.layers[0].name, "stem");
    assert_eq!(toy.layers[0].kind, "conv");
    assert_eq!(toy.layers[0].wshape, vec![4, 3, 3, 3]);
    assert!(toy.layers[0].site_signed);
    assert_eq!(toy.layers[1].kind, "fc");
    assert!(!toy.layers[1].relu);
    assert_eq!(toy.first_layer(), 0);
    assert_eq!(toy.last_layer(), 1);
    assert_eq!(toy.total_weight_params(), 4 * 3 * 3 * 3 + 3 * 4);
    assert_eq!(toy.eval_batch, 4);
    assert!(toy.qat_exe.is_none());
    assert!(toy.distill_exe.is_none());

    let g = toy.gran("layer");
    assert_eq!(g.fim_exe, "toy.layer.fim");
    assert_eq!(g.units.len(), 2);
    assert_eq!(g.units[0].name, "stem");
    assert_eq!(g.units[0].layer_ids, vec![0]);
    assert!(g.units[0].skip_shape.is_none());
    assert_eq!(g.units[1].topo, "gap_fc");
    assert_eq!(g.units[1].in_shape, vec![4, 4, 6, 6]);
    assert_eq!(g.units[1].out_shape, vec![4, 3]);

    // executable signatures parse through the shared runtime path
    let sigs = parse_sigs(&mf.json).expect("sigs");
    let sig = sigs.get("toy.layer.u0.fwd").expect("exe sig");
    assert_eq!(sig.inputs.len(), 7);
    assert_eq!(sig.inputs[0].0, "x");
    assert_eq!(sig.inputs[0].1, vec![4, 3, 6, 6]);
    assert_eq!(sig.outputs[0].1, vec![4, 4, 6, 6]);
}

#[test]
fn golden_manifest_roundtrips_through_json() {
    let text =
        std::fs::read_to_string(fixture_dir().join("manifest.json")).unwrap();
    let parsed = Json::parse(&text).expect("parse fixture");
    let rendered = parsed.to_string();
    let reparsed = Json::parse(&rendered).expect("reparse rendered");
    assert_eq!(parsed, reparsed, "Json writer must round-trip the manifest");
    // spot-check a deep path survives the round trip
    let shape = reparsed
        .req("models")
        .req("toy")
        .req("grans")
        .req("layer")
        .req("units")
        .as_arr()
        .unwrap()[1]
        .req("out_shape")
        .usize_vec();
    assert_eq!(shape, vec![4, 3]);
}
