//! Quantization-as-a-service tests: the persistent content-addressed
//! artifact store under the session cache, and the `brecq serve` daemon.
//!
//! Pinned properties:
//! - a cold cache key races (threads, sessions, *processes*) to exactly
//!   one compute, and every racer observes bit-identical artifacts;
//! - corrupted payloads and truncated indexes are detected, counted,
//!   discarded and recomputed — never served;
//! - a warm-store `exp table1` replays bit-identically with zero backend
//!   dispatches and zero publishes;
//! - a served batch is bit-identical (per `JobOutput::fingerprint`) to an
//!   in-process run, concurrent clients included, and a warm re-submit —
//!   same daemon or a restarted one on the same store — computes nothing;
//! - greedy NMS changes det scoring exactly as the fixture math says, is
//!   off by default, and stays thread-invariant when enabled.
//!
//! Everything runs on the hermetic synthetic environment.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use brecq::coordinator::experiments::{table1, ExpOpts};
use brecq::coordinator::Env;
use brecq::eval::{det_map, det_map_nms};
use brecq::model::{DetInfo, DetObj};
use brecq::pipeline::{ArtifactCache, ArtifactStore, EvalScore, JobSpec,
                      Method, Session};
use brecq::tensor::Tensor;
use brecq::util::pool;

/// `pool::set_threads` is process-global and libtest runs tests
/// concurrently: serialize the tests that pin a thread count.
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock_pool() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn env() -> Env {
    Env::bootstrap_synthetic().expect("synthetic environment")
}

/// Fresh per-test store directory (removed and recreated every run so a
/// previous run's artifacts can't turn a cold assertion warm).
fn tmp(name: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("brecq_qaas_{}_{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&d);
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn store_cache(dir: &PathBuf) -> ArtifactCache {
    ArtifactCache::with_store(Arc::new(ArtifactStore::open(dir).unwrap()))
}

/// The one on-disk file under `dir` with the given extension.
fn entry_file(dir: &PathBuf, ext: &str) -> PathBuf {
    let mut found: Vec<PathBuf> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().map_or(false, |e| e == ext))
        .collect();
    assert_eq!(found.len(), 1, "expected one .{ext} entry in {dir:?}");
    found.pop().unwrap()
}

/// Total backend dispatches since the session's env was created.
fn dispatches(s: &Session) -> u64 {
    s.env()
        .rt
        .hotspots(usize::MAX)
        .iter()
        .map(|(_, calls, _)| *calls)
        .sum()
}

// ---------------------------------------------------------------------
// Compute-once under races
// ---------------------------------------------------------------------

#[test]
fn racing_threads_and_sessions_compute_a_cold_key_once() {
    let dir = tmp("thread_race");
    // two caches over two independent store handles on one directory —
    // the in-process analogue of two sessions in two processes
    let c1 = store_cache(&dir);
    let c2 = store_cache(&dir);
    let builds = AtomicUsize::new(0);
    // a value whose bit pattern text round-trips would lose
    let val = f64::from_bits(0x3ff0_0000_0000_0001);
    let got: Vec<u64> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let c = if i % 2 == 0 { &c1 } else { &c2 };
                let builds = &builds;
                s.spawn(move || {
                    let v = c
                        .get_or_build("qaas/race", || {
                            builds.fetch_add(1, Ordering::SeqCst);
                            std::thread::sleep(
                                Duration::from_millis(40),
                            );
                            Ok(EvalScore(val))
                        })
                        .unwrap();
                    v.0.to_bits()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    assert_eq!(
        builds.load(Ordering::SeqCst),
        1,
        "a cold key must compute exactly once across racing sessions"
    );
    assert!(
        got.iter().all(|&b| b == val.to_bits()),
        "every racer must observe the computed bits exactly"
    );
    let p1 = c1.store().unwrap().stats().publishes;
    let p2 = c2.store().unwrap().stats().publishes;
    assert_eq!(p1 + p2, 1, "exactly one publish across both sessions");
    assert_eq!(c1.computes() + c2.computes(), 1);
    assert_eq!(
        c1.store_hits() + c2.store_hits(),
        1,
        "the non-computing session must load the published entry"
    );
}

/// Child half of the cross-process race: only does work when the parent
/// test set `BRECQ_STORE_RACE_DIR`; a plain `cargo test` run no-ops it.
#[test]
fn store_race_child_process_helper() {
    let Some(dir) = std::env::var_os("BRECQ_STORE_RACE_DIR") else {
        return;
    };
    let cache = store_cache(&PathBuf::from(dir));
    let v = cache
        .get_or_build("qaas/proc-race", || {
            std::thread::sleep(Duration::from_millis(150));
            Ok(EvalScore(0.8125))
        })
        .unwrap();
    println!(
        "QAAS_RACE computed={} fp={:016x}",
        cache.computes(),
        v.0.to_bits()
    );
}

#[test]
fn racing_processes_compute_a_cold_key_once() {
    let dir = tmp("proc_race");
    let exe = std::env::current_exe().unwrap();
    let children: Vec<_> = (0..3)
        .map(|_| {
            std::process::Command::new(&exe)
                .args([
                    "store_race_child_process_helper",
                    "--exact",
                    "--nocapture",
                ])
                .env("BRECQ_STORE_RACE_DIR", &dir)
                .stdout(std::process::Stdio::piped())
                .spawn()
                .unwrap()
        })
        .collect();
    let mut computes = 0usize;
    let mut fps: Vec<String> = Vec::new();
    for child in children {
        let out = child.wait_with_output().unwrap();
        assert!(out.status.success(), "race child failed");
        let text = String::from_utf8_lossy(&out.stdout);
        let line = text
            .lines()
            .find(|l| l.starts_with("QAAS_RACE "))
            .expect("race child must print its QAAS_RACE line");
        for field in line.split_whitespace() {
            if let Some(n) = field.strip_prefix("computed=") {
                computes += n.parse::<usize>().unwrap();
            }
            if let Some(h) = field.strip_prefix("fp=") {
                fps.push(h.to_string());
            }
        }
    }
    assert_eq!(fps.len(), 3, "every child reports a fingerprint");
    assert_eq!(
        computes, 1,
        "exactly one process may compute the cold key"
    );
    assert!(
        fps.windows(2).all(|w| w[0] == w[1]),
        "cross-process artifacts must be bit-identical: {fps:?}"
    );
}

// ---------------------------------------------------------------------
// Corruption detection
// ---------------------------------------------------------------------

#[test]
fn corrupt_payload_and_truncated_index_are_recomputed() {
    let dir = tmp("corrupt");
    let key = "qaas/corrupt";
    let build = || Ok(EvalScore(0.3125));

    let c1 = store_cache(&dir);
    let v1 = c1.get_or_build(key, build).unwrap();
    assert_eq!(c1.computes(), 1);

    // flip one payload byte behind the checksum's back
    let bin = entry_file(&dir, "bin");
    let mut bytes = std::fs::read(&bin).unwrap();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x40;
    std::fs::write(&bin, &bytes).unwrap();

    let c2 = store_cache(&dir);
    let v2 = c2.get_or_build(key, build).unwrap();
    assert_eq!(
        v2.0.to_bits(),
        v1.0.to_bits(),
        "recomputed value must equal the original"
    );
    assert_eq!(
        c2.computes(),
        1,
        "a corrupt entry must be recomputed, not served"
    );
    assert_eq!(c2.store().unwrap().stats().corrupt, 1);

    // the recompute republished a clean entry: next session store-hits
    let c3 = store_cache(&dir);
    c3.get_or_build(key, build).unwrap();
    assert_eq!(c3.computes(), 0);
    assert_eq!(c3.store_hits(), 1);
    assert_eq!(c3.store().unwrap().stats().corrupt, 0);

    // truncate the JSON index mid-document: same detect-and-recompute
    let idx = entry_file(&dir, "json");
    let text = std::fs::read(&idx).unwrap();
    std::fs::write(&idx, &text[..text.len() / 2]).unwrap();
    let c4 = store_cache(&dir);
    c4.get_or_build(key, build).unwrap();
    assert_eq!(c4.computes(), 1);
    assert_eq!(c4.store().unwrap().stats().corrupt, 1);
}

// ---------------------------------------------------------------------
// Warm-store replay (the acceptance property)
// ---------------------------------------------------------------------

#[test]
fn warm_store_replays_table1_bit_identically_with_zero_dispatches() {
    let _g = lock_pool();
    pool::set_threads(2);
    let dir = tmp("table1_store");
    let o = ExpOpts {
        iters: 4,
        calib_n: 32,
        seed: 0,
        seeds: 1,
        verbose: false,
    };
    // the Block cell's exact spec, for a bit-level fingerprint check on
    // top of the rendered-table comparison
    let block_spec = JobSpec {
        model: "resnet_s".into(),
        wbits: 2,
        iters: o.iters,
        calib_n: o.calib_n,
        seed: o.seed,
        ..JobSpec::default()
    };

    let cold = Session::with_store(
        env(),
        Arc::new(ArtifactStore::open(&dir).unwrap()),
    );
    let cold_md = table1(&cold, &o).unwrap().to_markdown();
    let cold_fp = cold.run(&block_spec).unwrap().fingerprint();
    assert!(cold.cache().computes() > 0, "cold run must compute");
    assert!(cold.cache().store().unwrap().stats().publishes > 0);
    assert!(dispatches(&cold) > 0, "cold run must hit the backend");

    // fresh env + fresh session on the same store: only the disk warm
    let warm = Session::with_store(
        env(),
        Arc::new(ArtifactStore::open(&dir).unwrap()),
    );
    let warm_md = table1(&warm, &o).unwrap().to_markdown();
    let warm_fp = warm.run(&block_spec).unwrap().fingerprint();
    assert_eq!(warm_md, cold_md, "warm table1 must render identically");
    assert_eq!(
        warm_fp, cold_fp,
        "warm job output must be bit-identical to the cold run"
    );
    assert_eq!(warm.cache().computes(), 0, "warm run must not compute");
    assert!(warm.cache().store_hits() > 0);
    assert_eq!(warm.cache().store().unwrap().stats().publishes, 0);
    assert_eq!(
        dispatches(&warm),
        0,
        "warm replay must not dispatch the backend at all"
    );
    pool::set_threads(0);
}

// ---------------------------------------------------------------------
// Serve daemon vs in-process run
// ---------------------------------------------------------------------

#[cfg(unix)]
mod serve {
    use super::*;
    use brecq::pipeline::serve::{control, spawn, submit, SubmitSummary};
    use brecq::util::json::Json;

    fn smoke_specs() -> Vec<JobSpec> {
        vec![
            JobSpec {
                model: "resnet_s".into(),
                wbits: 4,
                abits: Some(8),
                iters: 6,
                calib_n: 32,
                seed: 0,
                ..JobSpec::default()
            },
            JobSpec {
                model: "resnet_s".into(),
                method: Method::Omse,
                wbits: 4,
                calib_n: 32,
                seed: 0,
                ..JobSpec::default()
            },
        ]
    }

    fn wait_for_socket(sock: &PathBuf) {
        for _ in 0..400 {
            if sock.exists() {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("daemon socket {sock:?} never appeared");
    }

    fn result_fingerprints(s: &SubmitSummary) -> Vec<String> {
        s.results
            .iter()
            .map(|r| {
                r.as_ref()
                    .expect("job failed")
                    .get("fingerprint")
                    .and_then(Json::as_str)
                    .expect("result carries a fingerprint")
                    .to_string()
            })
            .collect()
    }

    fn done_computes(s: &SubmitSummary) -> usize {
        s.done
            .get("computes")
            .and_then(Json::as_usize)
            .expect("done event carries computes")
    }

    #[test]
    fn daemon_matches_in_process_run_and_warm_restart_is_free() {
        let _g = lock_pool();
        pool::set_threads(2);
        let specs = smoke_specs();

        // ground truth: a fresh in-process session, no store
        let refs: Vec<String> = {
            let s = Session::new(env());
            specs
                .iter()
                .map(|sp| {
                    format!("{:016x}", s.run(sp).unwrap().fingerprint())
                })
                .collect()
        };

        let dir = tmp("serve");
        let store_dir = dir.join("store");
        let sock = dir.join("d.sock");
        let daemon = spawn(
            Session::with_store(
                env(),
                Arc::new(ArtifactStore::open(&store_dir).unwrap()),
            ),
            sock.clone(),
            2,
        );
        wait_for_socket(&sock);
        assert_eq!(
            control(&sock, "ping")
                .unwrap()
                .get("event")
                .and_then(Json::as_str),
            Some("pong")
        );

        // two concurrent clients, one submitting in reverse order
        let (fwd, rev) = std::thread::scope(|s| {
            let fwd = s.spawn(|| submit(&sock, &specs, 0, None, |_| {}));
            let rev = s.spawn(|| {
                let mut r: Vec<JobSpec> = specs.clone();
                r.reverse();
                submit(&sock, &r, 0, None, |_| {})
            });
            (
                fwd.join().unwrap().unwrap(),
                rev.join().unwrap().unwrap(),
            )
        });
        assert_eq!(result_fingerprints(&fwd), refs);
        let mut rev_fps = result_fingerprints(&rev);
        rev_fps.reverse();
        assert_eq!(
            rev_fps, refs,
            "concurrent clients must see bit-identical results"
        );

        // warm re-submit on the live daemon: everything cached
        let warm = submit(&sock, &specs, 0, None, |_| {}).unwrap();
        assert_eq!(result_fingerprints(&warm), refs);
        assert_eq!(done_computes(&warm), 0, "warm batch must not compute");

        control(&sock, "shutdown").unwrap();
        daemon.join().unwrap().unwrap();
        assert!(!sock.exists(), "shutdown must remove the socket file");

        // restart on the same store with a fresh env: the disk alone
        // makes the batch free, across daemon lifetimes
        let daemon2 = spawn(
            Session::with_store(
                env(),
                Arc::new(ArtifactStore::open(&store_dir).unwrap()),
            ),
            sock.clone(),
            2,
        );
        wait_for_socket(&sock);
        let warm2 = submit(&sock, &specs, 0, None, |_| {}).unwrap();
        assert_eq!(result_fingerprints(&warm2), refs);
        assert_eq!(
            done_computes(&warm2),
            0,
            "restarted daemon must replay from the store"
        );
        control(&sock, "shutdown").unwrap();
        daemon2.join().unwrap().unwrap();
        pool::set_threads(0);
    }

    #[test]
    fn daemon_rejects_bad_batches_with_typed_errors() {
        let _g = lock_pool();
        let dir = tmp("serve_err");
        let sock = dir.join("d.sock");
        let daemon = spawn(Session::new(env()), sock.clone(), 1);
        wait_for_socket(&sock);

        // unknown model fails that job, not the daemon
        let bad = vec![JobSpec {
            model: "nope".into(),
            ..JobSpec::default()
        }];
        let s = submit(&sock, &bad, 0, None, |_| {}).unwrap();
        assert!(s.results[0].is_err());
        assert_eq!(done_computes(&s), 0);

        control(&sock, "shutdown").unwrap();
        daemon.join().unwrap().unwrap();
    }
}

// ---------------------------------------------------------------------
// Greedy NMS
// ---------------------------------------------------------------------

/// Hand-checked fixture: three anchors (two stacked on one object, one on
/// the other), zero regression deltas so each decoded box equals its
/// anchor. Without NMS the duplicate second-ranked box is a false
/// positive between two true positives: AP = (1 + 2/3) / 2 = 5/6. With
/// NMS it is suppressed (IoU 1.0 with the kept top box): AP = 1.
#[test]
fn greedy_nms_suppresses_duplicate_boxes_deterministically() {
    let det = DetInfo {
        anchors: vec![
            [0.3, 0.3, 0.2, 0.2],
            [0.3, 0.3, 0.2, 0.2],
            [0.7, 0.7, 0.2, 0.2],
        ],
        scenes: vec![vec![
            DetObj { anchor: 0, bbox: [0.3, 0.3, 0.2, 0.2] },
            DetObj { anchor: 2, bbox: [0.7, 0.7, 0.2, 0.2] },
        ]],
    };
    let mut row = vec![0f32; det.head_dim()];
    row[4] = 3.0; // anchor 0 objectness: top-ranked true positive
    row[9] = 2.0; // anchor 1: duplicate box, outranks the other object
    row[14] = 1.0; // anchor 2: second true positive
    let lg = Tensor::new(vec![1, det.head_dim()], row);
    let labels = [0usize];

    let plain = det_map_nms(&det, &lg, &labels, false);
    let suppressed = det_map_nms(&det, &lg, &labels, true);
    assert!(
        (plain - 5.0 / 6.0).abs() < 1e-12,
        "plain mAP should be 5/6, got {plain}"
    );
    assert!(
        (suppressed - 1.0).abs() < 1e-12,
        "NMS mAP should be 1.0, got {suppressed}"
    );
    // the default entry point stays NMS-free (table5 baselines)
    assert_eq!(det_map(&det, &lg, &labels).to_bits(), plain.to_bits());
}

/// `det_nms` rides the JobSpec: the eval artifact is keyed per flag (so
/// both variants coexist in one session) and the NMS path stays
/// bit-identical at 1, 2 and 8 threads.
#[test]
fn det_nms_job_is_keyed_separately_and_thread_invariant() {
    let _g = lock_pool();
    let spec = JobSpec {
        model: "det_s".into(),
        wbits: 4,
        abits: Some(8),
        iters: 6,
        calib_n: 32,
        seed: 0,
        det_nms: true,
        ..JobSpec::default()
    };

    pool::set_threads(1);
    let s = Session::new(env());
    let plain = s
        .run(&JobSpec { det_nms: false, ..spec.clone() })
        .unwrap()
        .accuracy
        .unwrap();
    let nms = s.run(&spec).unwrap().accuracy.unwrap();
    assert!((0.0..=1.0).contains(&plain));
    assert!((0.0..=1.0).contains(&nms));
    let keys: Vec<String> = s
        .cache()
        .per_key_stats()
        .into_iter()
        .map(|(k, _)| k)
        .collect();
    assert!(
        keys.iter().any(|k| k.ends_with("/eval/nms0")),
        "plain eval key missing: {keys:?}"
    );
    assert!(
        keys.iter().any(|k| k.ends_with("/eval/nms1")),
        "nms eval key missing: {keys:?}"
    );

    let mut bits = vec![nms.to_bits()];
    for nt in [2usize, 8] {
        pool::set_threads(nt);
        let s = Session::new(env());
        bits.push(s.run(&spec).unwrap().accuracy.unwrap().to_bits());
    }
    pool::set_threads(0);
    assert_eq!(bits[0], bits[1], "NMS mAP differs at 1 vs 2 threads");
    assert_eq!(bits[1], bits[2], "NMS mAP differs at 2 vs 8 threads");
}
