//! Reconstruction-plan parity properties: a compiled plan
//! (`runtime::plan`) must be **bit-identical** to the retained
//! per-dispatch `unit_recon` path — per step (losses, gv, gastep) and
//! end-to-end (per-unit loss curves, committed weights, learned act
//! steps) — at 1/2/8 threads, for every unit of all three synthetic
//! models (the classifiers and the det_s detection backbone)
//! at every exported granularity (single-node layer/block units and
//! multi-node stage/net/pack seq programs alike). Plus the warm-plan
//! zero-allocation guarantee on the scratch-arena counters (mirroring
//! the warm-kernel test in `tests/parallel.rs`), zero-fallback
//! accounting on the plan counters (delta reads — the counters are
//! process-global and cumulative), and the typed-error contract for
//! unknown granularity strings.

use std::sync::Mutex;

use brecq::calib::CalibSet;
use brecq::coordinator::Env;
use brecq::model::{ModelInfo, UnitInfo};
use brecq::quant::{
    act_bounds, mse_steps_per_channel, weight_bounds, AdaRoundState,
};
use brecq::recon::{BitConfig, Calibrator, ReconConfig};
use brecq::runtime::plan::{self, PlanInputs};
use brecq::runtime::Backend;
use brecq::tensor::Tensor;
use brecq::util::pool;
use brecq::util::rng::Rng;

/// `pool::set_threads` is process-global; serialize every test in this
/// binary (same rationale as `tests/parallel.rs`).
static POOL_LOCK: Mutex<()> = Mutex::new(());

fn lock_pool() -> std::sync::MutexGuard<'static, ()> {
    POOL_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn bits_of(t: &Tensor) -> Vec<u32> {
    t.data.iter().map(|v| v.to_bits()).collect()
}

fn batched(shape: &[usize], b: usize) -> Vec<usize> {
    let mut s = shape.to_vec();
    s[0] = b;
    s
}

/// Gaussian tensor with a deterministic sprinkling of IEEE edge values
/// (±0.0, denormals) — the kernels must fold them bit-exactly.
fn synth(rng: &mut Rng, shape: Vec<usize>) -> Tensor {
    let n: usize = shape.iter().product();
    let mut t = Tensor::new(
        shape,
        (0..n).map(|_| rng.gauss() as f32).collect(),
    );
    for (i, v) in t.data.iter_mut().enumerate() {
        match i % 13 {
            2 => *v = 0.0,
            5 => *v = -0.0,
            7 => *v = 1e-42,
            11 => *v = -1e-42,
            _ => {}
        }
    }
    t
}

/// Per-unit quantizer fixtures shared by the plan and dispatch sides.
struct UnitFixture {
    x: Tensor,
    skip: Option<Tensor>,
    z_fp: Tensor,
    fim: Option<Tensor>,
    wsteps: Vec<Tensor>,
    vs: Vec<Tensor>,
    asteps: Vec<Tensor>,
    wb: Vec<(Tensor, Tensor)>,
    ab: Vec<(Tensor, Tensor)>,
    wbounds: Vec<(f32, f32)>,
    abounds: Vec<(f32, f32)>,
    ones_fb: Tensor,
}

fn fixture(
    model: &ModelInfo,
    unit: &UnitInfo,
    ws: &[Tensor],
    k: usize,
    bsz: usize,
    use_fim: bool,
    seed: u64,
) -> UnitFixture {
    let mut rng = Rng::new(seed);
    let x = synth(&mut rng, batched(&unit.in_shape, k));
    let skip = unit
        .skip_shape
        .as_ref()
        .filter(|_| unit.uses_skip)
        .map(|sh| synth(&mut rng, batched(sh, k)));
    let z_fp = synth(&mut rng, batched(&unit.out_shape, k));
    let fim = use_fim.then(|| {
        synth(&mut rng, batched(&unit.out_shape, k))
            .map(|v| v.abs() + 0.25)
    });
    let mut wsteps = Vec::new();
    let mut vs = Vec::new();
    let mut asteps = Vec::new();
    let mut wb = Vec::new();
    let mut ab = Vec::new();
    let mut wbounds = Vec::new();
    let mut abounds = Vec::new();
    for &l in &unit.layer_ids {
        let steps = mse_steps_per_channel(&ws[l], 4);
        let st = AdaRoundState::init(&ws[l], &steps, 4);
        wsteps.push(st.steps_tensor());
        vs.push(st.v.clone());
        asteps.push(Tensor::scalar1(0.07));
        let (n, p) = weight_bounds(4);
        wb.push((Tensor::scalar1(n), Tensor::scalar1(p)));
        wbounds.push((n, p));
        let (lo, hi) = act_bounds(8, model.layers[l].site_signed);
        ab.push((Tensor::scalar1(lo), Tensor::scalar1(hi)));
        abounds.push((lo, hi));
    }
    let ones_fb = Tensor::full(batched(&unit.out_shape, bsz), 1.0);
    UnitFixture {
        x,
        skip,
        z_fp,
        fim,
        wsteps,
        vs,
        asteps,
        wb,
        ab,
        wbounds,
        abounds,
        ones_fb,
    }
}

/// Run plan steps and identical dispatches for every unit of one
/// granularity, asserting bitwise equality of all outputs.
fn assert_unit_parity(
    env: &Env,
    model_name: &str,
    gran: &str,
    aq: bool,
    use_fim: bool,
    threads: &[usize],
) {
    let model = env.model(model_name);
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let bsz = env.mf.calib_batch;
    let k = bsz + 16;
    let aq_flag = Tensor::scalar1(if aq { 1.0 } else { 0.0 });
    // (beta, lam): warmup (reg off), annealing, late phase
    let cases = [(20.0f32, 0.0f32), (10.0, 0.01), (2.0, 0.01)];

    for (ui, unit) in model.gran(gran).units.iter().enumerate() {
        let f = fixture(model, unit, &ws, k, bsz, use_fim, 90 + ui as u64);
        for &nt in threads {
            pool::set_threads(nt);
            let inputs = PlanInputs {
                x: &f.x,
                skip: f.skip.as_ref(),
                z_fp: &f.z_fp,
                fim: f.fim.as_ref(),
                ws: unit.layer_ids.iter().map(|&l| &ws[l]).collect(),
                bs: unit.layer_ids.iter().map(|&l| &bs[l]).collect(),
                wsteps: f.wsteps.iter().collect(),
                wbounds: f.wbounds.clone(),
                abounds: f.abounds.clone(),
                aq,
                batch: bsz,
            };
            let mut plan = env
                .rt
                .prepare_recon(&unit.recon_exe, inputs)
                .unwrap()
                .expect("every exported unit must compile to a plan");
            for (ci, &(beta, lam)) in cases.iter().enumerate() {
                let rows = Rng::new(500 + ci as u64)
                    .sample_indices(k, bsz);
                let s = plan
                    .step(&rows, &f.vs, &f.asteps, beta, lam)
                    .unwrap();

                // identical iteration through the dispatch path
                let xb = CalibSet::gather_rows(&f.x, &rows);
                let skb = f
                    .skip
                    .as_ref()
                    .map(|sk| CalibSet::gather_rows(sk, &rows));
                let zb = CalibSet::gather_rows(&f.z_fp, &rows);
                let fb_g = f
                    .fim
                    .as_ref()
                    .map(|t| CalibSet::gather_rows(t, &rows));
                let fb = fb_g.as_ref().unwrap_or(&f.ones_fb);
                let beta_t = Tensor::scalar1(beta);
                let lam_t = Tensor::scalar1(lam);
                let mut args: Vec<&Tensor> = vec![&xb];
                if unit.uses_skip {
                    args.push(skb.as_ref().unwrap());
                }
                args.push(&zb);
                args.push(fb);
                for (i, &l) in unit.layer_ids.iter().enumerate() {
                    args.push(&ws[l]);
                    args.push(&bs[l]);
                    args.push(&f.wsteps[i]);
                    args.push(&f.vs[i]);
                    args.push(&f.wb[i].0);
                    args.push(&f.wb[i].1);
                }
                for i in 0..unit.layer_ids.len() {
                    args.push(&f.asteps[i]);
                    args.push(&f.ab[i].0);
                    args.push(&f.ab[i].1);
                }
                args.push(&beta_t);
                args.push(&lam_t);
                args.push(&aq_flag);
                let out = env.rt.run(&unit.recon_exe, &args).unwrap();

                let ctx = format!(
                    "{model_name}/{gran} unit {} nt {nt} case {ci} \
                     aq {aq} fim {use_fim}",
                    unit.name
                );
                assert_eq!(
                    s.loss.to_bits(),
                    out[0].data[0].to_bits(),
                    "loss: {ctx}"
                );
                assert_eq!(
                    s.rec.to_bits(),
                    out[1].data[0].to_bits(),
                    "rec: {ctx}"
                );
                assert_eq!(
                    s.round.to_bits(),
                    out[2].data[0].to_bits(),
                    "round: {ctx}"
                );
                let nl = unit.layer_ids.len();
                for i in 0..nl {
                    assert_eq!(
                        bits_of(&plan.gv()[i]),
                        bits_of(&out[3 + i]),
                        "gv[{i}]: {ctx}"
                    );
                    assert_eq!(
                        plan.gsteps()[i].data[0].to_bits(),
                        out[3 + nl + i].data[0].to_bits(),
                        "gastep[{i}]: {ctx}"
                    );
                }
            }
        }
    }
    pool::set_threads(0);
}

#[test]
fn plan_step_matches_dispatch_resnet_block() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    assert_unit_parity(&env, "resnet_s", "block", false, true, &[1, 2, 8]);
    // MSE fallback (no FIM): plan's implicit unit weight vs the
    // dispatch path's all-ones tensor
    assert_unit_parity(&env, "resnet_s", "block", false, false, &[2]);
}

#[test]
fn plan_step_matches_dispatch_resnet_layer_aq() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    assert_unit_parity(&env, "resnet_s", "layer", true, true, &[1, 2, 8]);
}

#[test]
fn plan_step_matches_dispatch_mbv2_block() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    assert_unit_parity(
        &env,
        "mobilenetv2_s",
        "block",
        false,
        true,
        &[1, 2, 8],
    );
}

#[test]
fn plan_step_matches_dispatch_mbv2_layer_aq_mse() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    assert_unit_parity(
        &env,
        "mobilenetv2_s",
        "layer",
        true,
        false,
        &[1, 2],
    );
}

#[test]
fn plan_step_matches_dispatch_resnet_stage() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    assert_unit_parity(&env, "resnet_s", "stage", false, true, &[1, 2, 8]);
    // aq on: multi-node plans keep the LSQ chains across node joins
    assert_unit_parity(&env, "resnet_s", "stage", true, true, &[2]);
}

#[test]
fn plan_step_matches_dispatch_resnet_net() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    assert_unit_parity(&env, "resnet_s", "net", false, true, &[1, 2, 8]);
    // MSE fallback through a whole-net program
    assert_unit_parity(&env, "resnet_s", "net", false, false, &[2]);
    assert_unit_parity(&env, "resnet_s", "net", true, false, &[2]);
}

#[test]
fn plan_step_matches_dispatch_pack_both_models() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    // whatever partition the generator measured, every pack unit —
    // singleton block or multi-block seq — must compile and match
    assert_unit_parity(&env, "resnet_s", "pack", false, true, &[1, 2, 8]);
    assert_unit_parity(&env, "resnet_s", "pack", true, false, &[2]);
    assert_unit_parity(
        &env,
        "mobilenetv2_s",
        "pack",
        false,
        true,
        &[1, 2, 8],
    );
    assert_unit_parity(&env, "mobilenetv2_s", "pack", true, false, &[2]);
}

#[test]
fn plan_step_matches_dispatch_det_s() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    // det_s shares the resnet_s trunk geometry but carries its own
    // weights and a 20-wide regression head; every granularity must
    // step bit-identically to dispatch, like the classifiers
    assert_unit_parity(&env, "det_s", "block", false, true, &[1, 2, 8]);
    assert_unit_parity(&env, "det_s", "layer", true, true, &[2]);
    assert_unit_parity(&env, "det_s", "stage", false, true, &[1, 2, 8]);
    assert_unit_parity(&env, "det_s", "net", false, false, &[2]);
    assert_unit_parity(&env, "det_s", "pack", true, false, &[2]);
}

/// End-to-end: whole calibrations driven by plans vs the dispatch path
/// must produce identical loss curves, committed weights and act steps.
fn calibrate_fingerprint(
    env: &Env,
    model_name: &str,
    cfg: &ReconConfig,
    abits: Option<usize>,
) -> (Vec<(u64, u64)>, Vec<Vec<u32>>, Vec<u32>) {
    let model = env.model(model_name);
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    // per-model dataset: det_s calibrates on its own data_det/ scenes
    let train = env.train_set_for(model).unwrap();
    let calib = env.calib(&train, 32, 3);
    let bits = BitConfig::uniform(model, 4, abits, true);
    let qm = cal.calibrate(&calib, &bits, cfg).unwrap();
    (
        qm.reports
            .iter()
            .map(|r| (r.initial_loss.to_bits(), r.final_loss.to_bits()))
            .collect(),
        qm.weights.iter().map(bits_of).collect(),
        qm.act_steps.iter().map(|v| v.to_bits()).collect(),
    )
}

#[test]
fn calibrate_plan_vs_dispatch_bitwise_resnet() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    for nt in [1usize, 2, 8] {
        pool::set_threads(nt);
        let planned = calibrate_fingerprint(
            &env,
            "resnet_s",
            &ReconConfig { iters: 10, ..ReconConfig::default() },
            Some(8),
        );
        let dispatched = calibrate_fingerprint(
            &env,
            "resnet_s",
            &ReconConfig {
                iters: 10,
                plan: false,
                ..ReconConfig::default()
            },
            Some(8),
        );
        assert_eq!(planned, dispatched, "resnet_s W4A8 nt {nt}");
    }
    pool::set_threads(0);
}

#[test]
fn calibrate_plan_vs_dispatch_bitwise_mbv2() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    for nt in [1usize, 2, 8] {
        pool::set_threads(nt);
        let planned = calibrate_fingerprint(
            &env,
            "mobilenetv2_s",
            &ReconConfig { iters: 8, ..ReconConfig::default() },
            None,
        );
        let dispatched = calibrate_fingerprint(
            &env,
            "mobilenetv2_s",
            &ReconConfig {
                iters: 8,
                plan: false,
                ..ReconConfig::default()
            },
            None,
        );
        assert_eq!(planned, dispatched, "mobilenetv2_s W4 nt {nt}");
    }
    pool::set_threads(0);
}

#[test]
fn calibrate_plan_vs_dispatch_bitwise_mse_layer_and_multinode() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    pool::set_threads(2);
    // layer granularity, MSE objective, no rounding regularizer
    let base = ReconConfig {
        gran: "layer".into(),
        iters: 8,
        use_fim: false,
        round_reg: false,
        ..ReconConfig::default()
    };
    let planned = calibrate_fingerprint(&env, "resnet_s", &base, None);
    let dispatched = calibrate_fingerprint(
        &env,
        "resnet_s",
        &ReconConfig { plan: false, ..base.clone() },
        None,
    );
    assert_eq!(planned, dispatched, "resnet_s layer MSE");
    // stage granularity: the multi-node seq unit now compiles to a plan
    // (no dispatch fallback) and must stay bitwise equal to dispatch
    let stage = ReconConfig {
        gran: "stage".into(),
        iters: 6,
        ..ReconConfig::default()
    };
    let before = plan::snapshot();
    let planned = calibrate_fingerprint(&env, "resnet_s", &stage, None);
    let d = plan::snapshot().since(&before);
    assert_eq!(d.fallback_steps, 0, "stage seq units must compile");
    assert!(d.steps > 0, "stage calibration ran no plan steps");
    let dispatched = calibrate_fingerprint(
        &env,
        "resnet_s",
        &ReconConfig { plan: false, ..stage.clone() },
        None,
    );
    assert_eq!(planned, dispatched, "resnet_s stage seq plan");
    pool::set_threads(0);
}

#[test]
fn calibrate_plan_vs_dispatch_bitwise_det() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    for nt in [1usize, 2, 8] {
        pool::set_threads(nt);
        // use_fim defaults on: this also drives the detection FIM seed
        // (half-SSE gradient against the box-target rows)
        let planned = calibrate_fingerprint(
            &env,
            "det_s",
            &ReconConfig { iters: 8, ..ReconConfig::default() },
            Some(8),
        );
        let dispatched = calibrate_fingerprint(
            &env,
            "det_s",
            &ReconConfig {
                iters: 8,
                plan: false,
                ..ReconConfig::default()
            },
            Some(8),
        );
        assert_eq!(planned, dispatched, "det_s W4A8 nt {nt}");
    }
    pool::set_threads(0);
}

/// Every exported granularity of every model calibrates entirely on
/// compiled plans: the fallback counter must not move, and exactly one
/// plan is built per unit. Delta reads — the counters are cumulative
/// process-global atomics polluted by every earlier test in this
/// binary.
#[test]
fn every_granularity_calibrates_with_zero_fallback() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    pool::set_threads(2);
    for (mname, grans) in [
        ("resnet_s", &["layer", "block", "stage", "net", "pack"][..]),
        ("mobilenetv2_s", &["layer", "block", "pack"][..]),
        // the detection backbone reuses the conv/fc/gap unit vocabulary,
        // so its plans must compile exactly like the classifiers'
        ("det_s", &["layer", "block", "stage", "net", "pack"][..]),
    ] {
        for &gran in grans {
            let cfg = ReconConfig {
                gran: gran.into(),
                iters: 4,
                ..ReconConfig::default()
            };
            let before = plan::snapshot();
            calibrate_fingerprint(&env, mname, &cfg, None);
            let d = plan::snapshot().since(&before);
            assert_eq!(
                d.fallback_steps, 0,
                "{mname}/{gran} fell back to per-iteration dispatch"
            );
            let nunits = env.model(mname).gran(gran).units.len();
            assert_eq!(
                d.builds, nunits,
                "{mname}/{gran}: one plan per unit"
            );
            assert!(d.steps > 0, "{mname}/{gran} ran no plan steps");
        }
    }
    pool::set_threads(0);
}

/// A granularity typo (or one a model does not export) is a typed
/// error at every entry point — never a panic, never a silent
/// fallthrough to some other partition.
#[test]
fn unknown_granularity_is_a_typed_error() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    let model = env.model("resnet_s");
    // the validated lookup itself
    let err = model.try_gran("blcok").unwrap_err().to_string();
    assert!(
        err.contains("'blcok'") && err.contains("available"),
        "unhelpful error: {err}"
    );
    // end to end through ReconConfig.gran
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let train = env.train_set().unwrap();
    let calib = env.calib(&train, 8, 0);
    let bits = BitConfig::uniform(model, 4, None, true);
    let cfg = ReconConfig {
        gran: "blcok".into(),
        iters: 2,
        ..ReconConfig::default()
    };
    let err = cal.calibrate(&calib, &bits, &cfg).unwrap_err().to_string();
    assert!(err.contains("blcok"), "calibrate error: {err}");
    // a valid name the model does not export is equally loud
    let err = env
        .model("mobilenetv2_s")
        .try_gran("net")
        .unwrap_err()
        .to_string();
    assert!(err.contains("not exported"), "undeclared error: {err}");
}

/// The warm-plan zero-allocation guarantee: once a plan has stepped a
/// few times, further steps serve every scratch request from the
/// recycling arenas — the allocation counter must not move. (Counters
/// are process-global; every test in this binary serializes on
/// POOL_LOCK.)
fn assert_warm_plan_zero_alloc(env: &Env, model_name: &str, gran: &str) {
    let model = env.model(model_name);
    let cal = Calibrator::new(&env.rt, &env.mf, model);
    let (ws, bs) = cal.fp_weights().unwrap();
    let bsz = env.mf.calib_batch;
    let k = bsz + 16;
    // heaviest unit of the granularity
    let unit = model
        .gran(gran)
        .units
        .iter()
        .max_by_key(|u| {
            u.layer_ids
                .iter()
                .map(|&l| model.layers[l].macs)
                .sum::<u64>()
        })
        .unwrap();
    let f = fixture(model, unit, &ws, k, bsz, true, 7);
    for nt in [1usize, 4] {
        pool::set_threads(nt);
        let inputs = PlanInputs {
            x: &f.x,
            skip: f.skip.as_ref(),
            z_fp: &f.z_fp,
            fim: f.fim.as_ref(),
            ws: unit.layer_ids.iter().map(|&l| &ws[l]).collect(),
            bs: unit.layer_ids.iter().map(|&l| &bs[l]).collect(),
            wsteps: f.wsteps.iter().collect(),
            wbounds: f.wbounds.clone(),
            abounds: f.abounds.clone(),
            aq: false,
            batch: bsz,
        };
        let mut plan = env
            .rt
            .prepare_recon(&unit.recon_exe, inputs)
            .unwrap()
            .expect("plan");
        let mut rng = Rng::new(11);
        let mut step = |rng: &mut Rng| {
            let rows = rng.sample_indices(k, bsz);
            std::hint::black_box(
                plan.step(&rows, &f.vs, &f.asteps, 10.0, 0.01).unwrap(),
            );
        };
        for _ in 0..3 {
            step(&mut rng); // warm the plan + per-thread scratch sets
        }
        let (allocs_before, reuses_before) = pool::scratch_counters();
        for _ in 0..5 {
            step(&mut rng);
        }
        let (allocs_after, reuses_after) = pool::scratch_counters();
        assert_eq!(
            allocs_after, allocs_before,
            "warm plan steps allocated scratch at {nt} threads"
        );
        assert!(
            reuses_after > reuses_before,
            "scratch reuse counter did not advance at {nt} threads"
        );
    }
    pool::set_threads(0);
}

#[test]
fn warm_plan_steps_do_zero_scratch_allocations() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    assert_warm_plan_zero_alloc(&env, "resnet_s", "block");
}

#[test]
fn warm_multinode_plan_steps_do_zero_scratch_allocations() {
    let _g = lock_pool();
    let env = Env::bootstrap_synthetic().unwrap();
    // the whole-net seq program exercises the inter-node output and
    // gradient buffers on top of the per-layer scratch
    assert_warm_plan_zero_alloc(&env, "resnet_s", "net");
}
